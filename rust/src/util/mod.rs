//! Dependency-free substrates.
//!
//! The build environment is offline with only the `xla` crate closure
//! vendored, so the reproduction implements its own:
//!
//! * [`json`] — JSON parser/serializer (manifests, golden fixtures).
//! * [`cli`] — flag parser for the `ttq-serve` binary.
//! * [`benchkit`] — measurement harness (warmup, sampling, stats) used
//!   by all `benches/*` targets.
//! * [`propcheck`] — property-based testing: seeded case generation
//!   with failure-case reporting and input shrinking.

#![forbid(unsafe_code)]

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod propcheck;

/// Index of the largest element, first occurrence winning ties (the
/// greedy-decode convention shared by the eval accuracy path and the
/// server's reply loop). Returns 0 for an empty slice.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Numerically stable `ln Σᵢ exp(xᵢ)` over a logit row, accumulated in
/// `f64` after max-shifting — the one implementation shared by the
/// eval perplexity path ([`crate::backend`] NLL), the quality benches
/// and the online KL probe ([`crate::obs::quality`]). Returns
/// `f64::NEG_INFINITY` for an empty row (the sum over zero terms), and
/// stays finite whenever at least one input is finite (all-`-inf` rows
/// come back `-inf` rather than `NaN`).
pub fn logsumexp(row: &[f32]) -> f64 {
    let mut mx = f32::NEG_INFINITY;
    for &v in row {
        mx = mx.max(v);
    }
    if mx == f32::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    let mut z = 0.0f64;
    for &v in row {
        z += ((v - mx) as f64).exp();
    }
    z.ln() + mx as f64
}

#[cfg(test)]
mod tests {
    use super::{argmax, logsumexp};

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[]), 0);
        // ties: first occurrence wins (strict > comparison)
        assert_eq!(argmax(&[2.0, 7.0, 7.0]), 1);
        // NaN never beats an existing max under strict >
        assert_eq!(argmax(&[1.0, f32::NAN, 3.0]), 2);
    }

    #[test]
    fn logsumexp_matches_direct_sum_on_small_logits() {
        let xs = [0.5f32, -1.25, 2.0, 0.0];
        let direct: f64 = xs.iter().map(|&v| (v as f64).exp()).sum::<f64>().ln();
        assert!((logsumexp(&xs) - direct).abs() < 1e-12);
    }

    #[test]
    fn logsumexp_is_shift_invariant_and_overflow_safe() {
        let xs = [1.0f32, 2.0, 3.0];
        let base = logsumexp(&xs);
        let shifted: Vec<f32> = xs.iter().map(|v| v + 500.0).collect();
        // exp(503) overflows naively; the max-shift keeps it finite and
        // exactly `base + 500`.
        let s = logsumexp(&shifted);
        assert!(s.is_finite());
        assert!((s - (base + 500.0)).abs() < 1e-9, "{s} vs {}", base + 500.0);
    }

    #[test]
    fn logsumexp_edge_rows() {
        assert_eq!(logsumexp(&[]), f64::NEG_INFINITY);
        assert_eq!(logsumexp(&[f32::NEG_INFINITY; 3]), f64::NEG_INFINITY);
        // Single element: lse == the element.
        assert!((logsumexp(&[4.25]) - 4.25).abs() < 1e-12);
    }
}
