//! Dependency-free substrates.
//!
//! The build environment is offline with only the `xla` crate closure
//! vendored, so the reproduction implements its own:
//!
//! * [`json`] — JSON parser/serializer (manifests, golden fixtures).
//! * [`cli`] — flag parser for the `ttq-serve` binary.
//! * [`benchkit`] — measurement harness (warmup, sampling, stats) used
//!   by all `benches/*` targets.
//! * [`propcheck`] — property-based testing: seeded case generation
//!   with failure-case reporting and input shrinking.

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod propcheck;
