//! Property-based testing substrate (offline stand-in for proptest).
//!
//! Deterministic seeded generation, N cases per property, and greedy
//! input shrinking for the built-in generators. Used by
//! `rust/tests/quant_proptest.rs` and the coordinator invariants.

use crate::linalg::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    /// Cases generated per property.
    pub cases: usize,
    /// Base RNG seed (case i derives from it deterministically).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE }
    }
}

/// A generated test case plus the generator context.
pub struct Gen<'a> {
    /// The case's seeded random source.
    pub rng: &'a mut Rng,
}

impl<'a> Gen<'a> {
    /// Uniform usize in `lo..=hi`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform u32 in `lo..=hi`.
    pub fn u32_in(&mut self, lo: u32, hi: u32) -> u32 {
        self.usize_in(lo as usize, hi as usize) as u32
    }

    /// Standard-normal f32.
    pub fn f32_normal(&mut self) -> f32 {
        self.rng.normal() as f32
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.u01() * (hi - lo)
    }

    /// Uniformly pick one of `opts`.
    pub fn choose<'t, T>(&mut self, opts: &'t [T]) -> &'t T {
        &opts[self.rng.below(opts.len() as u64) as usize]
    }

    /// `n` standard-normal f32s.
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_normal()).collect()
    }

    /// Occasionally inject adversarial values (zeros, duplicates,
    /// huge magnitudes) — quantizers must survive them.
    pub fn vec_f32_adversarial(&mut self, n: usize) -> Vec<f32> {
        let mut v = self.vec_f32(n);
        match self.rng.below(4) {
            0 => v.iter_mut().for_each(|x| *x = 0.0),
            1 => {
                let c = v[0];
                v.iter_mut().for_each(|x| *x = c);
            }
            2 => v.iter_mut().step_by(3).for_each(|x| *x *= 1e6),
            _ => {}
        }
        v
    }
}

/// Run a property over `cfg.cases` generated cases. The property
/// returns `Err(description)` on failure; the harness reports the
/// case index and seed so the failure replays deterministically.
pub fn check<F>(name: &str, cfg: &Config, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed.wrapping_add(case as u64));
        let mut gen = Gen { rng: &mut rng };
        if let Err(msg) = prop(&mut gen) {
            panic!(
                "property '{name}' failed at case {case} \
                 (replay: seed {} + case): {msg}",
                cfg.seed
            );
        }
    }
}

/// Assert helper producing property-style errors.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("x*x >= 0", &Config { cases: 32, seed: 1 }, |g| {
            let x = g.f32_normal();
            if x * x >= 0.0 {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failure() {
        check("always fails", &Config { cases: 1, seed: 2 }, |_| {
            Err("nope".into())
        });
    }

    #[test]
    fn generators_in_range() {
        check("ranges", &Config::default(), |g| {
            let n = g.usize_in(3, 9);
            if !(3..=9).contains(&n) {
                return Err(format!("usize_in out of range: {n}"));
            }
            let f = g.f64_in(-1.0, 1.0);
            if !(-1.0..=1.0).contains(&f) {
                return Err(format!("f64_in out of range: {f}"));
            }
            let c = *g.choose(&[2u32, 3, 4, 5]);
            if !(2..=5).contains(&c) {
                return Err(format!("choose out of range: {c}"));
            }
            Ok(())
        });
    }

    #[test]
    fn adversarial_vectors_vary() {
        let mut rng = Rng::new(3);
        let mut g = Gen { rng: &mut rng };
        let mut saw_const = false;
        for _ in 0..64 {
            let v = g.vec_f32_adversarial(8);
            if v.windows(2).all(|w| w[0] == w[1]) {
                saw_const = true;
            }
        }
        assert!(saw_const, "adversarial generator never produced constants");
    }
}
