//! Native-backend correctness suite — runs with ZERO artifacts.
//!
//! Golden values (hand-computed uniform bound, an analytically
//! tractable opt forward), structural invariants (causality, batch-row
//! independence, determinism), calibrator-contract parity for the
//! native stats pass, packed-W4 execution parity, and the full
//! submit → batch → observe → drift-requantize → reply serving loop.

use std::time::Duration;

use ttq_serve::backend::{testmodel, ExecBackend, NativeBackend};
use ttq_serve::coordinator::{
    BatchPolicy, CalibratorConfig, OnlineCalibrator, ServeEvent, Server, ServerConfig,
};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::eval::{EvalConfig, Evaluator, MethodSpec};
use ttq_serve::linalg::Mat;
use ttq_serve::quant::{rtn_quantize, QuantSpec};

fn native() -> NativeBackend {
    NativeBackend::new(&ttq_serve::artifacts_dir())
}

fn prompt(stream: &mut CorpusStream, seq: usize) -> Vec<i32> {
    let mut toks = vec![BOS; seq];
    for t in toks.iter_mut().skip(1) {
        *t = stream.next_token();
    }
    toks
}

// ---------------------------------------------------------------------
// Golden values
// ---------------------------------------------------------------------

#[test]
fn zero_embedding_gives_exactly_uniform_nll() {
    // With embed ≡ 0 the entire forward is 0 (RMSNorm(0) = 0, attention
    // over zero values is 0, SwiGLU of 0 is 0), so logits ≡ 0 and the
    // per-token NLL is exactly ln(vocab) — a hand-computable pin.
    let be = native();
    let mut w = testmodel::build("qwen-micro").unwrap();
    let (vocab, d, seq) = (
        w.manifest.config.vocab,
        w.manifest.config.d_model,
        w.manifest.config.seq,
    );
    w.set("embed", Mat::zeros(vocab, d));
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let toks = s.batch(2, seq);
    let logits = be.logits(&w, &toks, 2).unwrap();
    assert!(logits.iter().all(|&v| v == 0.0), "logits must be exactly 0");
    let (nll, count) = be.nll(&w, &toks, 2).unwrap();
    assert_eq!(count as usize, 2 * (seq - 1));
    let per_token = nll / count;
    let want = (vocab as f64).ln();
    assert!(
        (per_token - want).abs() < 1e-4,
        "uniform nll {per_token} vs ln({vocab}) = {want}"
    );
}

#[test]
fn opt_uniform_attention_matches_hand_forward() {
    // Craft an analytically tractable opt model: wq = wk = 0 (attention
    // scores all 0 → exactly uniform over the causal prefix), wv = wo =
    // I (the attention block adds the running mean of LayerNorm(h)),
    // up = 0 (MLP contributes nothing), pos_embed = 0. The expected
    // forward is then computed here with straight-line loops and must
    // match the backend's optimized path.
    let be = native();
    let mut w = testmodel::build("opt-micro").unwrap();
    let cfg = w.manifest.config.clone();
    let (d, seq, vocab) = (cfg.d_model, cfg.seq, cfg.vocab);
    assert_eq!(cfg.n_heads * cfg.head_dim, d, "test assumes d_attn == d");
    w.set("pos_embed", Mat::zeros(cfg.max_seq, d));
    for l in 0..cfg.n_layers {
        w.set(&format!("l{l}.wq"), Mat::zeros(d, d));
        w.set(&format!("l{l}.wk"), Mat::zeros(d, d));
        w.set(&format!("l{l}.wv"), Mat::eye(d));
        w.set(&format!("l{l}.wo"), Mat::eye(d));
        w.set(&format!("l{l}.up"), Mat::zeros(cfg.d_mlp, d));
    }

    let mut s = CorpusStream::new("ptbs", Split::Eval);
    let toks = s.batch(1, seq);
    let got = be.logits(&w, &toks, 1).unwrap();

    // ---- independent reference forward (simple loops) ----
    let embed = w.get("embed").unwrap();
    let ln = |h: &[Vec<f32>]| -> Vec<Vec<f32>> {
        // weight 1, bias 0 (the untouched init)
        h.iter()
            .map(|row| {
                let mu = row.iter().sum::<f32>() / d as f32;
                let var =
                    row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
                let inv = 1.0 / (var + 1e-5f32).sqrt();
                row.iter().map(|&v| (v - mu) * inv).collect()
            })
            .collect()
    };
    let mut h: Vec<Vec<f32>> = toks
        .iter()
        .map(|&t| embed.row(t as usize).to_vec())
        .collect();
    for _layer in 0..cfg.n_layers {
        let x = ln(&h);
        // o[s] = uniform average of x[0..=s] (accumulated in the same
        // ascending order as the attention loop)
        for s_pos in (0..seq).rev() {
            let inv = 1.0 / (s_pos + 1) as f32;
            let mut o = vec![0.0f32; d];
            for xr in x.iter().take(s_pos + 1) {
                for (oj, &xj) in o.iter_mut().zip(xr) {
                    *oj += inv * xj;
                }
            }
            for (hj, oj) in h[s_pos].iter_mut().zip(&o) {
                *hj += oj;
            }
        }
        // MLP adds zero (up = 0 → relu(0) = 0)
    }
    let hf = ln(&h);
    for (s_pos, hrow) in hf.iter().enumerate() {
        for v in 0..vocab {
            let mut acc = 0.0f32;
            let erow = embed.row(v);
            for j in 0..d {
                acc += hrow[j] * erow[j];
            }
            let have = got[s_pos * vocab + v];
            assert!(
                (have - acc).abs() < 1e-3,
                "logit[{s_pos},{v}] = {have}, hand-computed {acc}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Structural invariants
// ---------------------------------------------------------------------

#[test]
fn forward_is_deterministic() {
    let be = native();
    let w = testmodel::build("gemma-micro").unwrap();
    let seq = w.manifest.config.seq;
    let mut s = CorpusStream::new("c4s", Split::Eval);
    let toks = s.batch(2, seq);
    let a = be.logits(&w, &toks, 2).unwrap();
    let b = be.logits(&w, &toks, 2).unwrap();
    assert_eq!(a, b, "same weights + tokens must be bit-identical");
}

#[test]
fn causal_mask_blocks_future_tokens() {
    let be = native();
    let w = testmodel::build("qwen-micro").unwrap();
    let (seq, vocab) = (w.manifest.config.seq, w.manifest.config.vocab);
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let toks = s.batch(1, seq);
    let base = be.logits(&w, &toks, 1).unwrap();
    let mut mutated = toks.clone();
    mutated[seq - 1] = (toks[seq - 1] + 7) % 512;
    let changed = be.logits(&w, &mutated, 1).unwrap();
    // every position before the mutation is bit-identical
    assert_eq!(
        base[..(seq - 1) * vocab],
        changed[..(seq - 1) * vocab],
        "future token leaked into past logits"
    );
    // ... and the mutated position actually changed
    assert_ne!(base[(seq - 1) * vocab..], changed[(seq - 1) * vocab..]);
}

#[test]
fn batch_rows_are_independent() {
    let be = native();
    let w = testmodel::build("opt-micro").unwrap();
    let (seq, vocab) = (w.manifest.config.seq, w.manifest.config.vocab);
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let p1 = prompt(&mut s, seq);
    let p2 = prompt(&mut s, seq);
    let mut both = p1.clone();
    both.extend_from_slice(&p2);
    let stacked = be.logits(&w, &both, 2).unwrap();
    let solo1 = be.logits(&w, &p1, 1).unwrap();
    let solo2 = be.logits(&w, &p2, 1).unwrap();
    assert_eq!(stacked[..seq * vocab], solo1[..]);
    assert_eq!(stacked[seq * vocab..], solo2[..]);
}

// ---------------------------------------------------------------------
// Stats ↔ calibrator contract
// ---------------------------------------------------------------------

#[test]
fn native_stats_feed_the_online_calibrator() {
    let be = native();
    let w = testmodel::build("qwen-micro").unwrap();
    let man = &w.manifest;
    let seq = man.config.seq;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let toks = s.batch(4, seq);
    let got = be.stats(&w, &toks, 4, true).unwrap();

    // shape contract: one ActStats per manifest linear, full p-grid
    assert_eq!(got.stats.len(), man.linears.len());
    for (st, lin) in got.stats.iter().zip(&man.linears) {
        assert_eq!(st.d_in(), lin.d_in, "{}", lin.name);
        assert_eq!(st.ps, man.norm_ps);
        assert!((st.count - (4 * seq) as f64).abs() < 1e-9);
        for row in &st.norm_sums {
            assert!(row.iter().all(|&v| v.is_finite() && v >= 0.0));
        }
    }
    // corr contract: PSD-shaped gram per linear (symmetric, diag ≥ 0)
    assert_eq!(got.corr.len(), man.linears.len());
    for (c, lin) in got.corr.iter().zip(&man.linears) {
        assert_eq!((c.rows, c.cols), (lin.d_in, lin.d_in));
        for i in 0..c.rows {
            assert!(c.at(i, i) >= 0.0);
            for j in 0..c.cols {
                assert_eq!(c.at(i, j), c.at(j, i), "gram asymmetric");
            }
        }
    }

    // the calibrator consumes them directly and commits usable diagonals
    let d_ins: Vec<usize> = man.linears.iter().map(|l| l.d_in).collect();
    let calib_cfg = CalibratorConfig::default().for_method(&MethodSpec::ttq(0));
    let mut calib = OnlineCalibrator::new(calib_cfg, &man.norm_ps, &d_ins);
    calib.observe(&got.stats);
    assert!(calib.needs_requant(), "fresh stats must trigger generation 1");
    let diags = calib.commit();
    assert_eq!(diags.len(), man.linears.len());
    for (dg, lin) in diags.iter().zip(&man.linears) {
        assert_eq!(dg.len(), lin.d_in);
        assert!(dg.iter().all(|&v| v.is_finite() && v > 0.0));
    }
}

// ---------------------------------------------------------------------
// Packed-W4 execution mode
// ---------------------------------------------------------------------

#[test]
fn packed_execution_matches_dense_on_rtn_weights() {
    // Running the packed backend over W equals running the dense
    // backend over RTN-dequantized W (same codes, same group params) —
    // only the summation order differs.
    let spec = QuantSpec::new(4, 32);
    let packed_be = native().with_exec_quant(spec.clone());
    let dense_be = native();

    let w = testmodel::build("qwen-micro").unwrap();
    let mut wq = testmodel::build("qwen-micro").unwrap();
    let linears = wq.manifest.linears.clone();
    for lin in &linears {
        let q = rtn_quantize(wq.get(&lin.name).unwrap(), &spec);
        wq.set(&lin.name, q);
    }
    let seq = w.manifest.config.seq;
    let mut s = CorpusStream::new("ptbs", Split::Eval);
    let toks = s.batch(2, seq);
    let packed = packed_be.logits(&w, &toks, 2).unwrap();
    let dense = dense_be.logits(&wq, &toks, 2).unwrap();
    assert_eq!(packed.len(), dense.len());
    for (a, b) in packed.iter().zip(&dense) {
        assert!((a - b).abs() < 1e-2, "packed {a} vs dense-on-RTN {b}");
    }
}

#[test]
fn packed_cache_tracks_weight_generations() {
    // Requantization (weights.set) must invalidate the packed cache —
    // stale packed weights would silently serve the old generation.
    let be = native().with_exec_quant(QuantSpec::new(4, 32));
    let mut w = testmodel::build("opt-micro").unwrap();
    let seq = w.manifest.config.seq;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let toks = s.batch(1, seq);
    let before = be.logits(&w, &toks, 1).unwrap();
    // zero one attention projection — the output must change
    let name = "l0.wq";
    let t = w.get(name).unwrap();
    let zeros = Mat::zeros(t.rows, t.cols);
    w.set(name, zeros);
    let after = be.logits(&w, &toks, 1).unwrap();
    assert_ne!(before, after, "packed cache served a stale generation");
}

// ---------------------------------------------------------------------
// Eval pipeline + the end-to-end serving loop (acceptance test)
// ---------------------------------------------------------------------

#[test]
fn eval_pipeline_runs_online_ttq_on_native() {
    let be = native();
    let weights = testmodel::build("qwen-micro").unwrap();
    let mut ev = Evaluator::with_weights(&be, weights);
    let cfg = EvalConfig {
        batch: 4,
        eval_batches: 2,
        calib_batches: 2,
        spec: QuantSpec::new(3, 32),
    };
    let fp = ev.perplexity(&MethodSpec::fp(), "wt2s", &cfg).unwrap();
    let ttq = ev.perplexity(&MethodSpec::ttq(0), "wt2s", &cfg).unwrap();
    assert!(fp.is_finite() && fp > 1.0);
    assert!(ttq.is_finite() && ttq > 1.0);
}

fn count_done(events: &[ServeEvent]) -> usize {
    events
        .iter()
        .filter(|e| matches!(e, ServeEvent::Done { .. }))
        .count()
}

#[test]
fn serving_loop_end_to_end_without_artifacts() {
    // The acceptance path: submit → batch → prefill/observe → drift-
    // triggered requantize → streamed decode, all on the native
    // backend, zero PJRT state.
    let be = native();
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.policy = BatchPolicy { buckets: vec![1, 4], linger: Duration::ZERO };
    cfg.spec = QuantSpec::new(4, 32);
    cfg.calib.drift_threshold = 0.005; // synthetic profiles are flat
    cfg.max_new_tokens = 4;
    let mut server = Server::new(&be, cfg).unwrap();
    let prompt_len = server.max_seq() / 2;

    // phase 1: one domain
    let mut a = CorpusStream::new("ptbs", Split::Eval);
    let mut done = 0usize;
    for _ in 0..12 {
        server.submit(prompt(&mut a, prompt_len));
    }
    done += count_done(&server.drain().unwrap());
    assert!(
        server.weight_generation() >= 1,
        "first batch must commit a weight generation"
    );
    let gens_before = server.weight_generation();

    // phase 2: shifted domain → the calibrator must requantize
    let mut b = CorpusStream::new("c4s", Split::Eval);
    for _ in 0..8 {
        for _ in 0..4 {
            server.submit(prompt(&mut b, prompt_len));
        }
        done += count_done(&server.drain().unwrap());
    }
    assert_eq!(done, 12 + 32, "every submitted request must complete");
    assert!(
        server.weight_generation() > gens_before,
        "domain shift did not requantize (gen stuck at {gens_before})"
    );
    use std::sync::atomic::Ordering::Relaxed;
    assert!(server.metrics.batches.load(Relaxed) < 44, "no batching happened");
    assert!(server.metrics.requants.load(Relaxed) >= 1);
    // the decode phase actually ran: 4 tokens per request, 3 from decode
    assert_eq!(server.metrics.decode_tokens.load(Relaxed), 44 * 3);
    assert!(server.cache_stats().high_water_tokens > 0);
}

#[test]
fn serving_loop_runs_in_packed_execution_mode() {
    // Same loop with the W4 packed executor: requantization bumps the
    // weight generation, which must repack transparently.
    let be = native().with_exec_quant(QuantSpec::new(4, 32));
    let mut cfg = ServerConfig::new("opt-micro");
    cfg.policy = BatchPolicy { buckets: vec![1, 4], linger: Duration::ZERO };
    cfg.max_new_tokens = 3;
    let mut server = Server::new(&be, cfg).unwrap();
    let prompt_len = server.max_seq() / 2;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    for _ in 0..8 {
        server.submit(prompt(&mut s, prompt_len));
    }
    let events = server.drain().unwrap();
    assert_eq!(count_done(&events), 8);
    for e in &events {
        if let ServeEvent::Token { token, .. } = e {
            assert!(*token >= 0 && (*token as usize) < 512);
        }
    }
    assert!(server.weight_generation() >= 1);
}
