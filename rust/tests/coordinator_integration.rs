//! Coordinator integration: the serving engine — batching, online
//! self-calibration, requantization on domain shift — over whichever
//! backend is available (PJRT with artifacts, native with synthetic
//! weights otherwise).

use std::time::{Duration, Instant};

use ttq_serve::backend::{ExecBackend, NativeBackend, PjrtBackend};
use ttq_serve::coordinator::{BatchPolicy, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::quant::QuantSpec;
use ttq_serve::runtime::Runtime;

fn backend() -> Box<dyn ExecBackend> {
    if ttq_serve::artifacts_ready() {
        let rt = Runtime::new(&ttq_serve::artifacts_dir()).expect("PJRT client");
        Box::new(PjrtBackend::new(rt))
    } else {
        Box::new(NativeBackend::new(&ttq_serve::artifacts_dir()))
    }
}

fn trained() -> bool {
    ttq_serve::artifacts_ready()
}

fn prompt(stream: &mut CorpusStream, seq: usize) -> Vec<i32> {
    let mut toks = vec![BOS; seq];
    for t in toks.iter_mut().skip(1) {
        *t = stream.next_token();
    }
    toks
}

#[test]
fn serves_all_requests_with_batching() {
    let be = backend();
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.policy = BatchPolicy { buckets: vec![1, 4], linger: Duration::ZERO };
    let mut server = Server::new(be.as_ref(), cfg).unwrap();
    let seq = server.seq();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let n = 10;
    for _ in 0..n {
        server.submit(prompt(&mut s, seq));
    }
    let replies = server.drain().unwrap();
    assert_eq!(replies.len(), n);
    // replies carry valid vocabulary tokens
    for r in &replies {
        assert!(r.next_token >= 0 && (r.next_token as usize) < 512);
    }
    // batching actually happened (10 requests in < 10 batches)
    let batches = server
        .metrics
        .batches
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(batches < n as u64, "batches {batches}");
}

#[test]
fn first_batch_triggers_initial_quantization() {
    let be = backend();
    let mut server = Server::new(be.as_ref(), ServerConfig::new("opt-micro")).unwrap();
    assert_eq!(server.weight_generation(), 0);
    let seq = server.seq();
    let mut s = CorpusStream::new("ptbs", Split::Eval);
    server.submit(prompt(&mut s, seq));
    let far = Instant::now() + Duration::from_secs(1);
    let replies = server.step(far).unwrap();
    assert_eq!(replies.len(), 1);
    assert!(server.weight_generation() >= 1, "no initial quantization");
}

#[test]
fn stable_traffic_does_not_thrash_requantization() {
    let be = backend();
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.policy = BatchPolicy { buckets: vec![4], linger: Duration::ZERO };
    let mut server = Server::new(be.as_ref(), cfg).unwrap();
    let seq = server.seq();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let rounds = 6;
    for _ in 0..rounds {
        for _ in 0..4 {
            server.submit(prompt(&mut s, seq));
        }
        server.drain().unwrap();
    }
    let gens = server.weight_generation();
    // trained activations settle fast; untrained synthetic profiles are
    // flatter/noisier, so only forbid per-batch thrashing there
    let bound = if trained() { 3 } else { rounds - 1 };
    assert!(
        gens <= bound,
        "same-domain traffic requantized {gens} times (thrashing)"
    );
}

#[test]
fn domain_shift_triggers_requantization() {
    let be = backend();
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.policy = BatchPolicy { buckets: vec![4], linger: Duration::ZERO };
    cfg.spec = QuantSpec::new(3, 32);
    if !trained() {
        // untrained models have weaker channel structure; lower the
        // drift bar so the *mechanism* is still exercised end-to-end
        cfg.calib.drift_threshold = 0.01;
    }
    let mut server = Server::new(be.as_ref(), cfg).unwrap();
    let seq = server.seq();
    let mut a = CorpusStream::new("ptbs", Split::Eval);
    for _ in 0..4 {
        for _ in 0..4 {
            server.submit(prompt(&mut a, seq));
        }
        server.drain().unwrap();
    }
    let gens_before = server.weight_generation();
    // shift to a very different domain; decay needs a few batches
    let mut b = CorpusStream::new("c4s", Split::Eval);
    for _ in 0..6 {
        for _ in 0..4 {
            server.submit(prompt(&mut b, seq));
        }
        server.drain().unwrap();
    }
    assert!(
        server.weight_generation() > gens_before,
        "domain shift did not trigger self-recalibration"
    );
}

#[test]
fn metrics_accumulate() {
    let be = backend();
    let mut server = Server::new(be.as_ref(), ServerConfig::new("opt-micro")).unwrap();
    let seq = server.seq();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    for _ in 0..4 {
        server.submit(prompt(&mut s, seq));
    }
    server.drain().unwrap();
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(server.metrics.requests.load(Relaxed), 4);
    assert!(server.metrics.tokens.load(Relaxed) >= (4 * seq) as u64);
    assert!(server.metrics.tokens_per_sec() > 0.0);
    let s = server.metrics.summary();
    assert!(s.contains("requests=4"), "{s}");
}
