//! Coordinator integration: the decode-engine serving loop — batching,
//! prefill/decode scheduling, online self-calibration, requantization
//! on domain shift.
//!
//! Serving runs on the native backend unconditionally: cached
//! prefill/decode has no PJRT artifact variant (fixed-shape AOT
//! executables), and the PJRT backend returns a clear unsupported
//! error for it — pinned below. When `make artifacts` has run, the
//! native backend picks up the trained weights, so the tighter
//! trained-model thresholds still apply.

use std::time::Duration;

use ttq_serve::backend::{ExecBackend, NativeBackend, PjrtBackend};
use ttq_serve::coordinator::{BatchPolicy, ServeEvent, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::kvcache::{KvCache, KvCacheConfig};
use ttq_serve::quant::QuantSpec;
use ttq_serve::runtime::Runtime;

fn backend() -> NativeBackend {
    NativeBackend::new(&ttq_serve::artifacts_dir())
}

fn trained() -> bool {
    ttq_serve::artifacts_ready()
}

fn prompt(stream: &mut CorpusStream, len: usize) -> Vec<i32> {
    let mut toks = vec![BOS; len];
    for t in toks.iter_mut().skip(1) {
        *t = stream.next_token();
    }
    toks
}

fn count_done(events: &[ServeEvent]) -> usize {
    events
        .iter()
        .filter(|e| matches!(e, ServeEvent::Done { .. }))
        .count()
}

#[test]
fn serves_all_requests_with_batching() {
    let be = backend();
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.policy = BatchPolicy { buckets: vec![1, 4], linger: Duration::ZERO };
    cfg.max_new_tokens = 3;
    let mut server = Server::new(&be, cfg).unwrap();
    let prompt_len = server.max_seq() / 2;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let n = 10;
    for _ in 0..n {
        server.submit(prompt(&mut s, prompt_len));
    }
    let events = server.drain().unwrap();
    assert_eq!(count_done(&events), n);
    // streamed tokens carry valid vocabulary ids
    for e in &events {
        if let ServeEvent::Token { token, .. } = e {
            assert!(*token >= 0 && (*token as usize) < 512);
        }
    }
    // batching actually happened (10 requests in < 10 prefill batches)
    let batches = server
        .metrics
        .batches
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(batches < n as u64, "batches {batches}");
}

#[test]
fn first_batch_triggers_initial_quantization() {
    let be = backend();
    let mut server = Server::new(&be, ServerConfig::new("opt-micro")).unwrap();
    assert_eq!(server.weight_generation(), 0);
    let seq = server.seq();
    let mut s = CorpusStream::new("ptbs", Split::Eval);
    server.submit(prompt(&mut s, seq));
    let events = server.drain().unwrap();
    assert_eq!(count_done(&events), 1);
    assert!(server.weight_generation() >= 1, "no initial quantization");
}

#[test]
fn stable_traffic_does_not_thrash_requantization() {
    let be = backend();
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.policy = BatchPolicy { buckets: vec![4], linger: Duration::ZERO };
    let mut server = Server::new(&be, cfg).unwrap();
    let seq = server.seq();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let rounds = 6;
    for _ in 0..rounds {
        for _ in 0..4 {
            server.submit(prompt(&mut s, seq));
        }
        server.drain().unwrap();
    }
    let gens = server.weight_generation();
    // trained activations settle fast; untrained synthetic profiles are
    // flatter/noisier, so only forbid per-batch thrashing there
    let bound = if trained() { 3 } else { rounds - 1 };
    assert!(
        gens <= bound,
        "same-domain traffic requantized {gens} times (thrashing)"
    );
}

#[test]
fn domain_shift_triggers_requantization() {
    let be = backend();
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.policy = BatchPolicy { buckets: vec![4], linger: Duration::ZERO };
    cfg.spec = QuantSpec::new(3, 32);
    if !trained() {
        // untrained models have weaker channel structure; lower the
        // drift bar so the *mechanism* is still exercised end-to-end
        cfg.calib.drift_threshold = 0.01;
    }
    let mut server = Server::new(&be, cfg).unwrap();
    let seq = server.seq();
    let mut a = CorpusStream::new("ptbs", Split::Eval);
    for _ in 0..4 {
        for _ in 0..4 {
            server.submit(prompt(&mut a, seq));
        }
        server.drain().unwrap();
    }
    let gens_before = server.weight_generation();
    // shift to a very different domain; decay needs a few batches
    let mut b = CorpusStream::new("c4s", Split::Eval);
    for _ in 0..6 {
        for _ in 0..4 {
            server.submit(prompt(&mut b, seq));
        }
        server.drain().unwrap();
    }
    assert!(
        server.weight_generation() > gens_before,
        "domain shift did not trigger self-recalibration"
    );
}

#[test]
fn metrics_accumulate() {
    let be = backend();
    let mut cfg = ServerConfig::new("opt-micro");
    cfg.max_new_tokens = 2;
    let mut server = Server::new(&be, cfg).unwrap();
    let prompt_len = server.max_seq() / 2;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    for _ in 0..4 {
        server.submit(prompt(&mut s, prompt_len));
    }
    server.drain().unwrap();
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(server.metrics.requests.load(Relaxed), 4);
    assert!(server.metrics.tokens.load(Relaxed) >= (4 * prompt_len) as u64);
    assert_eq!(server.metrics.prefill_tokens.load(Relaxed), (4 * prompt_len) as u64);
    assert_eq!(server.metrics.decode_tokens.load(Relaxed), 4);
    assert!(server.metrics.tokens_per_sec() > 0.0);
    let s = server.metrics.summary();
    assert!(s.contains("requests=4"), "{s}");
    assert!(s.contains("cache_hwm"), "{s}");
}

#[test]
fn pjrt_backend_rejects_cached_decode_with_clear_error() {
    // The prefill/decode split is native-only; the PJRT adapter must
    // say so instead of failing somewhere deep in artifact lookup.
    if !ttq_serve::artifacts_ready() {
        return; // no PJRT client without artifacts — native-only env
    }
    let rt = Runtime::new(&ttq_serve::artifacts_dir()).expect("PJRT client");
    let be = PjrtBackend::new(rt);
    let w = be.load_model("qwen-micro").unwrap();
    let mut cache = KvCache::new(KvCacheConfig::from_manifest(&w.manifest, 1));
    let id = cache.alloc().unwrap();
    let err = be
        .prefill(&w, &[0, 1, 2, 3], &mut cache, &[id], false)
        .unwrap_err();
    assert!(
        err.to_string().contains("KV-cache"),
        "unhelpful error: {err}"
    );
}
