//! Cross-language corpus check: the rust engine must emit exactly the
//! token streams the python engine trained on (bit-identical PRNG +
//! Markov structure), via the shared fixture `corpus_golden.json`.

use std::fs;

use ttq_serve::corpus::{CorpusStream, Split, DOMAINS};
use ttq_serve::util::json::Value;

#[test]
fn rust_streams_match_python_fixture_exactly() {
    let p = ttq_serve::artifacts_dir().join("corpus_golden.json");
    let Ok(s) = fs::read_to_string(&p) else {
        eprintln!("skipping: {p:?} not built");
        return;
    };
    let fixture = Value::parse(&s).expect("fixture parses");
    let mut checked = 0;
    for d in &DOMAINS {
        for split in [Split::Train, Split::Eval, Split::Calib] {
            let key = format!("{}/{}", d.name, split.name());
            let want: Vec<i32> = fixture
                .field(&key)
                .unwrap_or_else(|_| panic!("fixture missing {key}"))
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap() as i32)
                .collect();
            let got = CorpusStream::new(d.name, split).tokens(64);
            assert_eq!(
                got, want,
                "domain {} split {:?} diverged from python — \
                 the two corpus engines are out of sync",
                d.name, split
            );
            checked += 1;
        }
    }
    assert_eq!(checked, DOMAINS.len() * 3);
}

#[test]
fn long_streams_stay_in_spec() {
    for d in &DOMAINS {
        let toks = CorpusStream::new(d.name, Split::Eval).tokens(10_000);
        assert!(toks.iter().all(|&t| t >= 1 && t as usize <= d.vocab_used));
    }
}
