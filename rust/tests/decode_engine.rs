//! Decode-engine correctness suite — runs with ZERO artifacts.
//!
//! The acceptance contract: cached incremental decode matches full-prefix
//! recompute on every synthetic model family, in both fp32 and packed-W4
//! execution — token streams exactly, fp32 logits within the documented
//! kernel numerics contract (`util::FP32_MAX_ULPS` / `util::FP32_ABS_TOL`,
//! see docs/ARCHITECTURE.md § Kernel dispatch & numerics). In-process the
//! two sides still agree bit for bit — both run on the pool's one
//! selected ISA and the per-tile dots are shape-independent — but the
//! suite asserts the *documented* cross-ISA bound so the goldens stay
//! valid if decode and recompute ever run under different ISA selections.
//! Plus the serving-layer contracts: streaming event shape, continuous
//! batching at mixed positions, mid-generation drift→requantize, KV-slot
//! backpressure, and the padding-row stats regression (bucket slack must
//! never feed the calibrator).

use std::time::{Duration, Instant};

use ttq_serve::backend::{testmodel, ExecBackend, NativeBackend};
use ttq_serve::coordinator::{BatchPolicy, ServeEvent, Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::eval::Evaluator;
use ttq_serve::kvcache::{KvCache, KvCacheConfig};
use ttq_serve::quant::QuantSpec;
use ttq_serve::util::{argmax, assert_fp32_slices_close};

fn native() -> NativeBackend {
    NativeBackend::new(&ttq_serve::artifacts_dir())
}

fn prompt(stream: &mut CorpusStream, len: usize) -> Vec<i32> {
    let mut toks = vec![BOS; len];
    for t in toks.iter_mut().skip(1) {
        *t = stream.next_token();
    }
    toks
}

// ---------------------------------------------------------------------
// Golden: cached decode ≡ full recompute, bit for bit
// ---------------------------------------------------------------------

fn assert_cached_matches_recompute(model: &str, be: &NativeBackend) {
    let w = testmodel::build(model).unwrap();
    let (vocab, max_seq) = (w.manifest.config.vocab, w.manifest.config.max_seq);
    let prompt_len = max_seq / 2;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let mut toks = prompt(&mut s, prompt_len);

    let mut cache = KvCache::new(KvCacheConfig::from_manifest(&w.manifest, 2));
    let id = cache.alloc().unwrap();
    let step = be.prefill(&w, &toks, &mut cache, &[id], false).unwrap();
    let full = be.logits(&w, &toks, 1).unwrap();
    // fp32 logits compare under the documented ULP/abs bound (PR 10
    // relaxed these from assert_eq!; token streams below stay exact).
    assert_fp32_slices_close(
        &step.logits,
        &full[(prompt_len - 1) * vocab..],
        &format!("{model}: prefill logits vs full forward"),
    );

    let mut tok = argmax(&step.logits) as i32;
    for i in 0..8 {
        toks.push(tok);
        let out = be
            .decode_step(&w, &[tok], &mut cache, &[id], false)
            .unwrap();
        let full = be.logits(&w, &toks, 1).unwrap();
        assert_fp32_slices_close(
            &out.logits,
            &full[(toks.len() - 1) * vocab..],
            &format!("{model} decode step {i}: cached vs full recompute"),
        );
        tok = argmax(&out.logits) as i32;
    }
    assert_eq!(cache.len(id), prompt_len + 8);
}

#[test]
fn golden_cached_decode_fp32_all_families() {
    let be = native();
    for model in ["opt-micro", "qwen-micro", "gemma-micro"] {
        assert_cached_matches_recompute(model, &be);
    }
}

#[test]
fn golden_cached_decode_packed_w4_all_families() {
    let be = native().with_exec_quant(QuantSpec::new(4, 32));
    for model in ["opt-micro", "qwen-micro", "gemma-micro"] {
        assert_cached_matches_recompute(model, &be);
    }
}

#[test]
fn batched_decode_matches_solo_at_mixed_positions() {
    // Continuous batching: sequences at different lengths decoded in one
    // batch must produce exactly the logits of solo decoding.
    let be = native();
    let w = testmodel::build("qwen-micro").unwrap();
    let mut s = CorpusStream::new("c4s", Split::Eval);
    let p1 = prompt(&mut s, 20);
    let p2 = prompt(&mut s, 29);

    // solo reference: per-step logits of each sequence alone
    let solo = |p: &[i32]| -> Vec<Vec<f32>> {
        let mut cache = KvCache::new(KvCacheConfig::from_manifest(&w.manifest, 1));
        let id = cache.alloc().unwrap();
        let mut out = Vec::new();
        let step = be.prefill(&w, p, &mut cache, &[id], false).unwrap();
        let mut tok = argmax(&step.logits) as i32;
        out.push(step.logits);
        for _ in 0..6 {
            let step = be
                .decode_step(&w, &[tok], &mut cache, &[id], false)
                .unwrap();
            tok = argmax(&step.logits) as i32;
            out.push(step.logits);
        }
        out
    };
    let ref1 = solo(&p1);
    let ref2 = solo(&p2);

    // joint: separate prefills (different lengths), joint decode batch
    let mut cache = KvCache::new(KvCacheConfig::from_manifest(&w.manifest, 2));
    let a = cache.alloc().unwrap();
    let b = cache.alloc().unwrap();
    let s1 = be.prefill(&w, &p1, &mut cache, &[a], false).unwrap();
    let s2 = be.prefill(&w, &p2, &mut cache, &[b], false).unwrap();
    assert_fp32_slices_close(&s1.logits, &ref1[0], "joint prefill seq 1");
    assert_fp32_slices_close(&s2.logits, &ref2[0], "joint prefill seq 2");
    let mut t1 = argmax(&s1.logits) as i32;
    let mut t2 = argmax(&s2.logits) as i32;
    let vocab = w.manifest.config.vocab;
    for i in 1..=6 {
        let out = be
            .decode_step(&w, &[t1, t2], &mut cache, &[a, b], false)
            .unwrap();
        assert_fp32_slices_close(&out.logits[..vocab], &ref1[i], &format!("seq 1 step {i}"));
        assert_fp32_slices_close(&out.logits[vocab..], &ref2[i], &format!("seq 2 step {i}"));
        t1 = argmax(&out.logits[..vocab]) as i32;
        t2 = argmax(&out.logits[vocab..]) as i32;
    }
}

#[test]
fn evaluator_generate_matches_full_recompute_argmax() {
    let be = native();
    let ev = Evaluator::new(&be, "gemma-micro").unwrap();
    let vocab = ev.weights.manifest.config.vocab;
    let mut s = CorpusStream::new("ptbs", Split::Eval);
    let p = prompt(&mut s, 24);
    let got = ev.generate(&p, 6, None).unwrap();
    // reference: greedy over full-prefix recompute
    let mut toks = p.clone();
    let mut want = Vec::new();
    for _ in 0..6 {
        let logits = be.logits(&ev.weights, &toks, 1).unwrap();
        let tok = argmax(&logits[(toks.len() - 1) * vocab..]) as i32;
        want.push(tok);
        toks.push(tok);
    }
    assert_eq!(got, want);
}

// ---------------------------------------------------------------------
// Serving layer: streaming events, stop conditions, backpressure
// ---------------------------------------------------------------------

#[test]
fn event_stream_contract_with_mixed_prompt_lengths() {
    let be = native();
    let mut cfg = ServerConfig::new("opt-micro");
    cfg.policy = BatchPolicy { buckets: vec![1, 4], linger: Duration::ZERO };
    cfg.max_new_tokens = 5;
    let mut server = Server::new(&be, cfg).unwrap();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    // mixed lengths in one fired batch exercise the length grouping
    let ids = [
        server.submit(prompt(&mut s, 16)),
        server.submit(prompt(&mut s, 24)),
        server.submit(prompt(&mut s, 24)),
        server.submit(prompt(&mut s, 16)),
    ];
    let events = server.drain().unwrap();
    for rid in ids {
        let toks: Vec<i32> = events
            .iter()
            .filter_map(|e| match e {
                ServeEvent::Token { id, token, .. } if *id == rid => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(toks.len(), 5, "request {rid} token stream");
        let indices: Vec<usize> = events
            .iter()
            .filter_map(|e| match e {
                ServeEvent::Token { id, index, .. } if *id == rid => Some(*index),
                _ => None,
            })
            .collect();
        assert_eq!(indices, vec![0, 1, 2, 3, 4], "indices stream in order");
        let dones: Vec<&ServeEvent> = events
            .iter()
            .filter(|e| matches!(e, ServeEvent::Done { id, .. } if *id == rid))
            .collect();
        assert_eq!(dones.len(), 1, "exactly one Done per request");
        match dones[0] {
            ServeEvent::Done { tokens, .. } => {
                assert_eq!(tokens, &toks, "Done carries the streamed tokens")
            }
            _ => unreachable!(),
        }
    }
    assert_eq!(server.running(), 0);
    assert_eq!(server.cache_stats().active_seqs, 0, "slots recycled");
    assert!(server.cache_stats().high_water_tokens > 0);
}

#[test]
fn full_context_prompt_yields_exactly_one_token() {
    // prompt_len == max_seq leaves no decode room — the engine degrades
    // to the pre-decode-engine one-shot behavior.
    let be = native();
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.max_new_tokens = 16;
    let mut server = Server::new(&be, cfg).unwrap();
    let max_seq = server.max_seq();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    server.submit(prompt(&mut s, max_seq));
    let events = server.drain().unwrap();
    let tokens = events
        .iter()
        .filter(|e| matches!(e, ServeEvent::Token { .. }))
        .count();
    assert_eq!(tokens, 1);
    assert!(matches!(
        events.last().unwrap(),
        ServeEvent::Done { tokens, .. } if tokens.len() == 1
    ));
}

#[test]
fn eos_token_stops_generation_early() {
    let be = native();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let p = prompt(&mut s, 24);
    // discover the deterministic second generated token, then use it as EOS
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.max_new_tokens = 6;
    let mut probe = Server::new(&be, cfg.clone()).unwrap();
    probe.submit(p.clone());
    let events = probe.drain().unwrap();
    let second = events
        .iter()
        .filter_map(|e| match e {
            ServeEvent::Token { token, index: 1, .. } => Some(*token),
            _ => None,
        })
        .next()
        .unwrap();

    cfg.eos = Some(second);
    let mut server = Server::new(&be, cfg).unwrap();
    server.submit(p);
    let events = server.drain().unwrap();
    match events.last().unwrap() {
        ServeEvent::Done { tokens, .. } => {
            // stops the moment EOS is emitted (index 1, or 0 if the
            // first token happens to coincide) — never the full budget
            assert!(tokens.len() <= 2, "generation ran past EOS: {tokens:?}");
            assert_eq!(*tokens.last().unwrap(), second);
        }
        e => panic!("expected Done, got {e:?}"),
    }
}

#[test]
fn cache_backpressure_requeues_and_serves_everything() {
    let be = native();
    let mut cfg = ServerConfig::new("opt-micro");
    cfg.policy = BatchPolicy { buckets: vec![4], linger: Duration::ZERO };
    cfg.cache_slots = 2; // smaller than the bucket — forces requeueing
    cfg.max_new_tokens = 3;
    let mut server = Server::new(&be, cfg).unwrap();
    let mut s = CorpusStream::new("c4s", Split::Eval);
    let n = 6;
    for _ in 0..n {
        server.submit(prompt(&mut s, 20));
    }
    let events = server.drain().unwrap();
    let done = events
        .iter()
        .filter(|e| matches!(e, ServeEvent::Done { .. }))
        .count();
    assert_eq!(done, n, "every request must complete despite 2 KV slots");
    assert!(server.cache_stats().high_water_tokens <= 2 * server.max_seq());
}

// ---------------------------------------------------------------------
// Mid-stream drift → requantize (the TTQ continuous-calibration claim)
// ---------------------------------------------------------------------

fn assert_midstream_requant(be: &NativeBackend) {
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.policy = BatchPolicy { buckets: vec![1], linger: Duration::ZERO };
    cfg.max_new_tokens = 10;
    // hair-trigger drift: every per-token stats observation requantizes
    cfg.calib.drift_threshold = 1e-9;
    let mut server = Server::new(be, cfg).unwrap();
    let prompt_len = server.max_seq() / 2;
    let mut s = CorpusStream::new("ptbs", Split::Eval);
    server.submit(prompt(&mut s, prompt_len));
    let events = server.drain().unwrap();
    let gens: Vec<u64> = events
        .iter()
        .filter_map(|e| match e {
            ServeEvent::Token { weight_generation, .. } => Some(*weight_generation),
            _ => None,
        })
        .collect();
    assert_eq!(gens.len(), 10);
    assert!(
        gens.windows(2).all(|w| w[0] <= w[1]),
        "weight generation must be monotone: {gens:?}"
    );
    assert!(
        gens.last().unwrap() > gens.first().unwrap(),
        "no mid-stream requantization observed in token events: {gens:?}"
    );
    assert!(matches!(events.last().unwrap(), ServeEvent::Done { .. }));
}

#[test]
fn midstream_requant_bumps_generation_in_token_events() {
    assert_midstream_requant(&native());
}

#[test]
fn midstream_requant_repacks_w4_execution() {
    // same loop under packed execution: each weight generation must
    // repack transparently (version-keyed cache) and keep serving
    assert_midstream_requant(&native().with_exec_quant(QuantSpec::new(4, 32)));
}

// ---------------------------------------------------------------------
// Padding regression: bucket slack must never feed the calibrator
// ---------------------------------------------------------------------

#[test]
fn padded_batch_and_unpadded_equivalent_produce_identical_diagonals() {
    let be = native();
    let n_linears = testmodel::build("qwen-micro").unwrap().manifest.linears.len();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let prompts: Vec<Vec<i32>> = (0..3).map(|_| prompt(&mut s, 32)).collect();

    let run = |buckets: Vec<usize>| -> (Vec<Vec<f32>>, Vec<i32>, u64) {
        let mut cfg = ServerConfig::new("qwen-micro");
        cfg.policy = BatchPolicy { buckets, linger: Duration::ZERO };
        cfg.max_new_tokens = 3;
        let mut server = Server::new(&be, cfg).unwrap();
        for p in &prompts {
            server.submit(p.clone());
        }
        let events = server.drain().unwrap();
        let toks: Vec<i32> = events
            .iter()
            .filter_map(|e| match e {
                ServeEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        let diags: Vec<Vec<f32>> =
            (0..n_linears).map(|i| server.calibrator().diag(i)).collect();
        let padded = server
            .metrics
            .padded_rows
            .load(std::sync::atomic::Ordering::Relaxed);
        (diags, toks, padded)
    };

    // bucket 4 fires a padded batch (3 real + 1 slack row); bucket 3 is
    // exact — the calibrator state must be bitwise identical either way
    let (diag_padded, toks_padded, slack) = run(vec![4]);
    let (diag_exact, toks_exact, no_slack) = run(vec![3]);
    assert_eq!(slack, 1, "test setup: the bucket-4 batch must carry slack");
    assert_eq!(no_slack, 0);
    assert_eq!(toks_padded, toks_exact, "token streams must agree");
    assert_eq!(
        diag_padded, diag_exact,
        "bucket padding leaked into the calibrator diagonals"
    );
}

// ---------------------------------------------------------------------
// Drain uses force_flush (no fabricated clock)
// ---------------------------------------------------------------------

#[test]
fn drain_flushes_lingering_requests_immediately() {
    let be = native();
    let mut cfg = ServerConfig::new("opt-micro");
    // a linger long enough that a fabricated-now bug would stall
    cfg.policy = BatchPolicy { buckets: vec![1, 4], linger: Duration::from_secs(3600) };
    cfg.max_new_tokens = 2;
    let mut server = Server::new(&be, cfg).unwrap();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    server.submit(prompt(&mut s, 16));
    // a poll-based step does nothing before the linger deadline
    assert!(server.step().unwrap().is_empty());
    assert_eq!(server.pending(), 1);
    let t0 = Instant::now();
    let events = server.drain().unwrap();
    assert!(t0.elapsed() < Duration::from_secs(60), "drain must not wait out linger");
    assert_eq!(
        events
            .iter()
            .filter(|e| matches!(e, ServeEvent::Done { .. }))
            .count(),
        1
    );
}
