//! End-to-end evaluation invariants over real artifacts — the paper's
//! qualitative claims at miniature scale. These are the most important
//! tests in the repo: they assert the *shape* of the results the
//! benches then report quantitatively.

use ttq_serve::eval::{EvalConfig, Evaluator, MethodSpec};
use ttq_serve::quant::QuantSpec;
use ttq_serve::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    if !ttq_serve::artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(&ttq_serve::artifacts_dir()).expect("PJRT client"))
}

fn fast_cfg(bits: u32, group: usize) -> EvalConfig {
    EvalConfig {
        batch: 4,
        eval_batches: 4,
        calib_batches: 6,
        spec: QuantSpec::new(bits, group),
    }
}

#[test]
fn trained_model_beats_uniform() {
    let Some(rt) = runtime() else { return };
    let mut ev = Evaluator::new(&rt, "qwen-micro").unwrap();
    let ppl = ev
        .perplexity(&MethodSpec::fp(), "wt2s", &fast_cfg(4, 32))
        .unwrap();
    assert!(ppl < 512.0 * 0.5, "fp ppl {ppl} — training failed?");
    assert!(ppl > 1.0);
}

#[test]
fn five_bit_close_to_fp() {
    // Paper: "5-bit quantization achieves nearly un-quantized
    // performance for most cases."
    let Some(rt) = runtime() else { return };
    let mut ev = Evaluator::new(&rt, "qwen-micro").unwrap();
    let cfg = fast_cfg(5, 32);
    let fp = ev.perplexity(&MethodSpec::fp(), "wt2s", &cfg).unwrap();
    let ttq = ev
        .perplexity(&MethodSpec::ttq(0), "wt2s", &cfg)
        .unwrap();
    assert!(ttq < fp * 1.10, "5-bit TTQ {ttq} vs fp {fp}");
}

#[test]
fn rtn_degrades_at_2_bits_ttq_less() {
    // The core Table-3 ordering at q=2: FP < TTQ < RTN. Note on
    // magnitude: the paper's RTN collapse (ppl 10³-10⁶) needs the
    // outlier activation channels of billion-param LLMs; our miniature
    // models are intrinsically robust, so the reproduction target is
    // the *ordering* plus visible degradation (EXPERIMENTS.md §Scope).
    let Some(rt) = runtime() else { return };
    let mut ev = Evaluator::new(&rt, "qwen-micro").unwrap();
    let cfg = fast_cfg(2, 32);
    let fp = ev.perplexity(&MethodSpec::fp(), "wt2s", &cfg).unwrap();
    let rtn = ev.perplexity(&MethodSpec::rtn(), "wt2s", &cfg).unwrap();
    let ttq = ev
        .perplexity(&MethodSpec::ttq(16), "wt2s", &cfg)
        .unwrap();
    assert!(rtn > fp * 1.05, "2-bit RTN should visibly degrade: {rtn} vs {fp}");
    assert!(ttq < rtn, "TTQ(r=16) {ttq} must beat RTN {rtn}");
    assert!(ttq > fp, "quantization can't beat FP on average: {ttq} vs {fp}");
}

#[test]
fn ttq_at_least_matches_mismatched_awq_at_3_bits() {
    // Domain-shift claim (Fig. 1): AWQ calibrated on a *different*
    // domain must not beat TTQ calibrated online on the eval domain.
    let Some(rt) = runtime() else { return };
    let mut ev = Evaluator::new(&rt, "qwen-micro").unwrap();
    let cfg = fast_cfg(3, 32);
    let awq_shifted = ev
        .perplexity(&MethodSpec::awq("c4s"), "ptbs", &cfg)
        .unwrap();
    let ttq = ev
        .perplexity(&MethodSpec::ttq(0), "ptbs", &cfg)
        .unwrap();
    assert!(
        ttq <= awq_shifted * 1.05,
        "TTQ {ttq} vs domain-shifted AWQ {awq_shifted}"
    );
}

#[test]
fn lowrank_compensation_helps_at_2_bits() {
    let Some(rt) = runtime() else { return };
    let mut ev = Evaluator::new(&rt, "opt-mini").unwrap();
    let cfg = fast_cfg(2, 32);
    let r0 = ev
        .perplexity(&MethodSpec::ttq(0), "wt2s", &cfg)
        .unwrap();
    let r16 = ev
        .perplexity(&MethodSpec::ttq(16), "wt2s", &cfg)
        .unwrap();
    assert!(
        r16 < r0 * 1.02,
        "TTQ r=16 ({r16}) should be <= TTQ r=0 ({r0}) at 2 bits"
    );
}

#[test]
fn gptq_beats_rtn() {
    let Some(rt) = runtime() else { return };
    let mut ev = Evaluator::new(&rt, "opt-micro").unwrap();
    let mut cfg = fast_cfg(2, 32);
    cfg.calib_batches = 4; // corr pass is heavier
    let rtn = ev.perplexity(&MethodSpec::rtn(), "wt2s", &cfg).unwrap();
    let gptq = ev
        .perplexity(&MethodSpec::gptq("wt2s"), "wt2s", &cfg)
        .unwrap();
    assert!(gptq < rtn, "GPTQ {gptq} must beat RTN {rtn} at 2 bits");
}

#[test]
fn restore_recovers_fp_exactly() {
    // Paper point (3): the original weights stay recoverable.
    let Some(rt) = runtime() else { return };
    let mut ev = Evaluator::new(&rt, "opt-micro").unwrap();
    let cfg = fast_cfg(2, 32);
    let fp1 = ev.perplexity(&MethodSpec::fp(), "wt2s", &cfg).unwrap();
    let _ = ev.perplexity(&MethodSpec::rtn(), "wt2s", &cfg).unwrap();
    let fp2 = ev.perplexity(&MethodSpec::fp(), "wt2s", &cfg).unwrap();
    assert!((fp1 - fp2).abs() < 1e-6, "restore leaked state: {fp1} vs {fp2}");
}

#[test]
fn accuracy_pipeline_runs_and_fp_is_best_ballpark() {
    let Some(rt) = runtime() else { return };
    let mut ev = Evaluator::new(&rt, "qwen-micro").unwrap();
    let cfg = fast_cfg(2, 32);
    let fp = ev.accuracy(&MethodSpec::fp(), "vqas", &cfg).unwrap();
    let rtn = ev.accuracy(&MethodSpec::rtn(), "vqas", &cfg).unwrap();
    assert!(fp > 0.2, "fp accuracy {fp} too low — model undertrained?");
    assert!(rtn <= fp + 0.02, "2-bit RTN {rtn} should not beat FP {fp}");
}
