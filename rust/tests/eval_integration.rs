//! End-to-end evaluation invariants — the paper's qualitative claims at
//! miniature scale. These are the most important tests in the repo:
//! they assert the *shape* of the results the benches then report
//! quantitatively.
//!
//! The pipeline tests run on whichever backend is available: PJRT over
//! trained artifacts when `make artifacts` has run, the native backend
//! over deterministic synthetic weights otherwise. Quality-ordering
//! assertions (trained-model claims) additionally require the trained
//! artifacts and skip on synthetic weights.

use ttq_serve::backend::{ExecBackend, NativeBackend, PjrtBackend};
use ttq_serve::eval::{EvalConfig, Evaluator, MethodSpec};
use ttq_serve::quant::QuantSpec;
use ttq_serve::runtime::Runtime;

fn backend() -> Box<dyn ExecBackend> {
    if ttq_serve::artifacts_ready() {
        let rt = Runtime::new(&ttq_serve::artifacts_dir()).expect("PJRT client");
        Box::new(PjrtBackend::new(rt))
    } else {
        Box::new(NativeBackend::new(&ttq_serve::artifacts_dir()))
    }
}

/// Trained artifacts present? (Quality-ordering claims need training;
/// the synthetic fallback only validates pipeline mechanics.)
fn trained() -> bool {
    ttq_serve::artifacts_ready()
}

fn fast_cfg(bits: u32, group: usize) -> EvalConfig {
    EvalConfig {
        batch: 4,
        eval_batches: 4,
        calib_batches: 6,
        spec: QuantSpec::new(bits, group),
    }
}

#[test]
fn fp_perplexity_is_finite_and_sane() {
    let be = backend();
    let mut ev = Evaluator::new(be.as_ref(), "qwen-micro").unwrap();
    let ppl = ev
        .perplexity(&MethodSpec::fp(), "wt2s", &fast_cfg(4, 32))
        .unwrap();
    assert!(ppl.is_finite() && ppl > 1.0, "fp ppl {ppl}");
    // an untrained model sits near the uniform bound; nothing sits above
    // vocab by more than numerical noise
    assert!(ppl < 512.0 * 1.5, "fp ppl {ppl} above uniform bound");
}

#[test]
fn trained_model_beats_uniform() {
    if !trained() {
        eprintln!("skipping: needs trained artifacts");
        return;
    }
    let be = backend();
    let mut ev = Evaluator::new(be.as_ref(), "qwen-micro").unwrap();
    let ppl = ev
        .perplexity(&MethodSpec::fp(), "wt2s", &fast_cfg(4, 32))
        .unwrap();
    assert!(ppl < 512.0 * 0.5, "fp ppl {ppl} — training failed?");
    assert!(ppl > 1.0);
}

#[test]
fn five_bit_close_to_fp() {
    // Paper: "5-bit quantization achieves nearly un-quantized
    // performance for most cases." Holds for any fixed model — 5-bit
    // QDQ is a small perturbation — so it runs on both backends.
    let be = backend();
    let mut ev = Evaluator::new(be.as_ref(), "qwen-micro").unwrap();
    let cfg = fast_cfg(5, 32);
    let fp = ev.perplexity(&MethodSpec::fp(), "wt2s", &cfg).unwrap();
    let ttq = ev
        .perplexity(&MethodSpec::ttq(0), "wt2s", &cfg)
        .unwrap();
    assert!(ttq < fp * 1.10, "5-bit TTQ {ttq} vs fp {fp}");
}

#[test]
fn rtn_degrades_at_2_bits_ttq_less() {
    // The core Table-3 ordering at q=2: FP < TTQ < RTN. Note on
    // magnitude: the paper's RTN collapse (ppl 10³-10⁶) needs the
    // outlier activation channels of billion-param LLMs; our miniature
    // models are intrinsically robust, so the reproduction target is
    // the *ordering* plus visible degradation (EXPERIMENTS.md §Scope).
    if !trained() {
        eprintln!("skipping: ordering claims need trained artifacts");
        return;
    }
    let be = backend();
    let mut ev = Evaluator::new(be.as_ref(), "qwen-micro").unwrap();
    let cfg = fast_cfg(2, 32);
    let fp = ev.perplexity(&MethodSpec::fp(), "wt2s", &cfg).unwrap();
    let rtn = ev.perplexity(&MethodSpec::rtn(), "wt2s", &cfg).unwrap();
    let ttq = ev
        .perplexity(&MethodSpec::ttq(16), "wt2s", &cfg)
        .unwrap();
    assert!(rtn > fp * 1.05, "2-bit RTN should visibly degrade: {rtn} vs {fp}");
    assert!(ttq < rtn, "TTQ(r=16) {ttq} must beat RTN {rtn}");
    assert!(ttq > fp, "quantization can't beat FP on average: {ttq} vs {fp}");
}

#[test]
fn ttq_at_least_matches_mismatched_awq_at_3_bits() {
    // Domain-shift claim (Fig. 1): AWQ calibrated on a *different*
    // domain must not beat TTQ calibrated online on the eval domain.
    if !trained() {
        eprintln!("skipping: ordering claims need trained artifacts");
        return;
    }
    let be = backend();
    let mut ev = Evaluator::new(be.as_ref(), "qwen-micro").unwrap();
    let cfg = fast_cfg(3, 32);
    let awq_shifted = ev
        .perplexity(&MethodSpec::awq("c4s"), "ptbs", &cfg)
        .unwrap();
    let ttq = ev
        .perplexity(&MethodSpec::ttq(0), "ptbs", &cfg)
        .unwrap();
    assert!(
        ttq <= awq_shifted * 1.05,
        "TTQ {ttq} vs domain-shifted AWQ {awq_shifted}"
    );
}

#[test]
fn lowrank_compensation_helps_at_2_bits() {
    if !trained() {
        eprintln!("skipping: ordering claims need trained artifacts");
        return;
    }
    let be = backend();
    let mut ev = Evaluator::new(be.as_ref(), "opt-mini").unwrap();
    let cfg = fast_cfg(2, 32);
    let r0 = ev
        .perplexity(&MethodSpec::ttq(0), "wt2s", &cfg)
        .unwrap();
    let r16 = ev
        .perplexity(&MethodSpec::ttq(16), "wt2s", &cfg)
        .unwrap();
    assert!(
        r16 < r0 * 1.02,
        "TTQ r=16 ({r16}) should be <= TTQ r=0 ({r0}) at 2 bits"
    );
}

#[test]
fn gptq_beats_rtn() {
    if !trained() {
        eprintln!("skipping: ordering claims need trained artifacts");
        return;
    }
    let be = backend();
    let mut ev = Evaluator::new(be.as_ref(), "opt-micro").unwrap();
    let mut cfg = fast_cfg(2, 32);
    cfg.calib_batches = 4; // corr pass is heavier
    let rtn = ev.perplexity(&MethodSpec::rtn(), "wt2s", &cfg).unwrap();
    let gptq = ev
        .perplexity(&MethodSpec::gptq("wt2s"), "wt2s", &cfg)
        .unwrap();
    assert!(gptq < rtn, "GPTQ {gptq} must beat RTN {rtn} at 2 bits");
}

#[test]
fn gptq_pipeline_runs_on_any_backend() {
    // The corr pass → Cholesky → greedy OBS path must *execute* even on
    // synthetic weights (quality claims live in `gptq_beats_rtn`).
    let be = backend();
    let mut ev = Evaluator::new(be.as_ref(), "opt-micro").unwrap();
    let mut cfg = fast_cfg(3, 32);
    cfg.calib_batches = 2;
    cfg.eval_batches = 2;
    let p = ev
        .perplexity(&MethodSpec::gptq("wt2s"), "wt2s", &cfg)
        .unwrap();
    assert!(p.is_finite() && p > 1.0, "gptq ppl {p}");
}

#[test]
fn restore_recovers_fp_exactly() {
    // Paper point (3): the original weights stay recoverable. Holds for
    // any weights — trained or synthetic.
    let be = backend();
    let mut ev = Evaluator::new(be.as_ref(), "opt-micro").unwrap();
    let cfg = fast_cfg(2, 32);
    let fp1 = ev.perplexity(&MethodSpec::fp(), "wt2s", &cfg).unwrap();
    let _ = ev.perplexity(&MethodSpec::rtn(), "wt2s", &cfg).unwrap();
    let fp2 = ev.perplexity(&MethodSpec::fp(), "wt2s", &cfg).unwrap();
    assert!((fp1 - fp2).abs() < 1e-6, "restore leaked state: {fp1} vs {fp2}");
}

#[test]
fn accuracy_pipeline_runs_and_is_a_rate() {
    let be = backend();
    let mut ev = Evaluator::new(be.as_ref(), "qwen-micro").unwrap();
    let cfg = fast_cfg(2, 32);
    let fp = ev.accuracy(&MethodSpec::fp(), "vqas", &cfg).unwrap();
    let rtn = ev.accuracy(&MethodSpec::rtn(), "vqas", &cfg).unwrap();
    assert!((0.0..=1.0).contains(&fp), "fp accuracy {fp}");
    assert!((0.0..=1.0).contains(&rtn), "rtn accuracy {rtn}");
    if trained() {
        assert!(fp > 0.2, "fp accuracy {fp} too low — model undertrained?");
        assert!(rtn <= fp + 0.02, "2-bit RTN {rtn} should not beat FP {fp}");
    }
}
