//! Exhaustive interleaving models of the trace-ring recorder
//! (`ttq_serve::obs::TraceBuffer`), run on the in-tree model checker
//! with the ring compiled against instrumented primitives.
//!
//! This target only contains tests under `--cfg loom`:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_obs
//! ```
//!
//! Each model states the seqlock invariant it checks; the matching
//! ordering comments in `rust/src/obs/trace.rs` cite these names. The
//! payload invariant used throughout is `b == a ^ MAGIC`: any torn
//! read (mixing words from two different records, or reading a
//! half-written slot) breaks it.
#![cfg(loom)]

use std::sync::Arc;

use ttq_serve::obs::{SpanKind, TraceBuffer, TraceEvent};
use ttq_serve::sync::model::Model;
use ttq_serve::sync::thread::spawn_named;

const MAGIC: u64 = 0x5bd1_e995_9bd1_e995;

fn ev(a: u64) -> TraceEvent {
    TraceEvent {
        kind: SpanKind::Kernel,
        seq: a,
        start_us: a,
        dur_us: a,
        weight_version: a,
        a,
        b: a ^ MAGIC,
    }
}

fn model() -> Model {
    // Defaults (preemption bound 2, 20k schedules) unless overridden
    // via TTQ_LOOM_* environment variables.
    Model::default()
}

/// Invariant (cited by the odd/even sequence-word comments in
/// `record`): a snapshot taken concurrently with two writers never
/// returns a torn record — every returned event satisfies the payload
/// invariant, on every bounded interleaving. With capacity 2 and two
/// writers racing for tickets, both same-slot overwrite and
/// publish-while-reading schedules are explored.
#[test]
fn writers_never_tear() {
    let report = model().try_check(|| {
        let tb = Arc::new(TraceBuffer::new(2));
        let t1 = {
            let tb = tb.clone();
            spawn_named("writer-1", move || tb.record(&ev(1)))
        };
        let t2 = {
            let tb = tb.clone();
            spawn_named("writer-2", move || tb.record(&ev(2)))
        };
        // reader races both writers
        for e in tb.snapshot() {
            assert_eq!(e.b, e.a ^ MAGIC, "torn record escaped the seqlock");
            assert!(e.a == 1 || e.a == 2, "payload from nowhere");
        }
        t1.join().expect("writer 1");
        t2.join().expect("writer 2");
        // quiescent: both records published, none torn
        let snap = tb.snapshot();
        assert_eq!(snap.len(), 2, "both published records retained");
        for e in &snap {
            assert_eq!(e.b, e.a ^ MAGIC);
        }
        assert_eq!(tb.recorded(), 2);
        assert_eq!(tb.dropped(), 0);
    });
    assert!(report.failure.is_none(), "model failed: {:?}", report.failure);
    assert!(report.schedules > 1, "recording must have interleavings");
}

/// Invariant (cited by the wraparound note on `record`): a full ring
/// never blocks a writer — the oldest record is overwritten instead —
/// and a concurrent reader of the contended slot either skips it or
/// reads one of the two records whole, never a mix. Capacity 1 forces
/// both writers onto the same slot.
#[test]
fn wraparound_drops_oldest_never_blocks() {
    let report = model().try_check(|| {
        let tb = Arc::new(TraceBuffer::new(1));
        let writer = {
            let tb = tb.clone();
            spawn_named("writer", move || tb.record(&ev(7)))
        };
        tb.record(&ev(9));
        for e in tb.snapshot() {
            assert_eq!(e.b, e.a ^ MAGIC, "torn record on the contended slot");
            assert!(e.a == 7 || e.a == 9);
        }
        writer.join().expect("writer completes");
        // Quiescent: at most one survivor. If the *overwritten* ticket's
        // writer finished last, its older publish word stomps the slot
        // and the newest ticket's record is unreadable — a legal drop,
        // never a torn read.
        let snap = tb.snapshot();
        assert!(snap.len() <= 1, "capacity-1 ring holds at most one record");
        for e in &snap {
            assert_eq!(e.b, e.a ^ MAGIC);
        }
        assert_eq!(tb.recorded(), 2);
        assert_eq!(tb.dropped(), 1);
    });
    assert!(report.failure.is_none(), "model failed: {:?}", report.failure);
    assert!(report.schedules > 1, "same-slot race must have interleavings");
}

/// Invariant (cited by the before/after sequence-word check in
/// `snapshot`): a slot mid-write is *skipped*, not returned — a reader
/// concurrent with a single writer sees either the empty ring or the
/// one fully published record, and the ticket counter is monotone
/// across the race.
#[test]
fn snapshot_skips_in_progress_slots() {
    let report = model().try_check(|| {
        let tb = Arc::new(TraceBuffer::new(2));
        let writer = {
            let tb = tb.clone();
            spawn_named("writer", move || tb.record(&ev(5)))
        };
        let snap = tb.snapshot();
        assert!(snap.len() <= 1, "one writer can publish at most one record");
        for e in &snap {
            assert_eq!(e.a, 5);
            assert_eq!(e.b, e.a ^ MAGIC, "half-written slot returned");
        }
        writer.join().expect("writer completes");
        assert_eq!(tb.snapshot().len(), 1, "published record visible after join");
        assert_eq!(tb.recorded(), 1);
    });
    assert!(report.failure.is_none(), "model failed: {:?}", report.failure);
    assert!(report.schedules > 1, "reader/writer race must have interleavings");
}
