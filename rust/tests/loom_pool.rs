//! Exhaustive interleaving models of the `WorkerPool` dispatch
//! protocol, run on the in-tree model checker (`ttq_serve::sync::model`)
//! with the pool compiled against instrumented primitives.
//!
//! This target only contains tests under `--cfg loom`:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_pool
//! ```
//!
//! Each model states the protocol invariant it checks; the matching
//! `SAFETY:`/ordering comments in `rust/src/linalg/pool.rs` cite these
//! names. Kernels deliberately perform only *plain* memory writes (no
//! instrumented ops) so an exploration abort can never be confused with
//! a kernel panic by the pool's `catch_unwind`.
#![cfg(loom)]

use std::panic::AssertUnwindSafe;
use std::sync::Arc;

use ttq_serve::linalg::pool::{WorkerPool, MT_FLOP_FLOOR};
use ttq_serve::sync::model::Model;
use ttq_serve::sync::thread::spawn_named;

const FORCE: usize = MT_FLOP_FLOOR;

fn model() -> Model {
    // Defaults (preemption bound 2, 20k schedules) unless overridden
    // via TTQ_LOOM_* environment variables.
    Model::default()
}

/// Invariant: every chunk index is claimed by exactly one lane, and
/// every row is written exactly once — on every bounded interleaving
/// of worker and dispatcher. (Cited by the `Ordering::Relaxed` comment
/// on the chunk-claim `fetch_add` and the `SendPtr` SAFETY comment.)
#[test]
fn chunks_claimed_exactly_once() {
    let report = model().try_check(|| {
        let pool = WorkerPool::new(2);
        let mut data = vec![0u32; 3];
        pool.run_rows(&mut data, 3, 1, FORCE, |_r0, w| {
            for v in w.iter_mut() {
                *v += 1;
            }
        });
        assert_eq!(data, vec![1, 1, 1], "row visited other than exactly once");
    });
    assert!(report.failure.is_none(), "model failed: {:?}", report.failure);
    assert!(report.schedules > 1, "pool dispatch must have interleavings");
}

/// Invariant: the `done` signal cannot be missed across *consecutive*
/// dispatches — the epoch handshake never lets the dispatcher sleep
/// through the last worker check-in, and a stale worker can never
/// double-serve an old job. A missed signal deadlocks the dispatcher,
/// which the checker reports on the schedule that loses it. (Cited by
/// the `'static` transmute SAFETY comment.)
#[test]
fn done_signal_not_missed() {
    let report = model().try_check(|| {
        let pool = WorkerPool::new(2);
        let mut a = vec![0u32; 2];
        pool.run_rows(&mut a, 2, 1, FORCE, |_r0, w| {
            for v in w.iter_mut() {
                *v += 1;
            }
        });
        let mut b = vec![0u32; 2];
        pool.run_rows(&mut b, 2, 1, FORCE, |_r0, w| {
            for v in w.iter_mut() {
                *v += 10;
            }
        });
        assert_eq!(a, vec![1, 1], "first dispatch corrupted");
        assert_eq!(b, vec![10, 10], "second dispatch corrupted");
    });
    assert!(report.failure.is_none(), "model failed: {:?}", report.failure);
}

/// Invariant: a panicking kernel chunk propagates its payload to the
/// dispatching thread on every interleaving, remaining chunks drain,
/// and the pool stays serviceable afterwards (gate released, workers
/// alive, state cleared).
#[test]
fn panic_payload_propagates() {
    let report = model().try_check(|| {
        let pool = WorkerPool::new(2);
        let mut data = vec![0u32; 2];
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_rows(&mut data, 2, 1, FORCE, |r0, _w| {
                if r0 == 0 {
                    panic!("chunk 0 exploded");
                }
            });
        }));
        assert!(r.is_err(), "kernel panic must reach the dispatcher");
        let mut after = vec![0u32; 2];
        pool.run_rows(&mut after, 2, 1, FORCE, |_r0, w| {
            for v in w.iter_mut() {
                *v += 1;
            }
        });
        assert_eq!(after, vec![1, 1], "pool bricked after kernel panic");
    });
    assert!(report.failure.is_none(), "model failed: {:?}", report.failure);
}

/// Invariant: concurrent dispatchers serialize on `dispatch_gate` —
/// the single-occupancy job slot is never overwritten mid-dispatch and
/// both callers complete with correct output on every interleaving.
#[test]
fn concurrent_dispatchers_serialize() {
    let report = model().try_check(|| {
        let pool = Arc::new(WorkerPool::new(2));
        let p2 = pool.clone();
        let other = spawn_named("dispatcher-2", move || {
            let mut b = vec![0u32; 2];
            p2.run_rows(&mut b, 2, 1, FORCE, |_r0, w| {
                for v in w.iter_mut() {
                    *v += 10;
                }
            });
            b
        });
        let mut a = vec![0u32; 2];
        pool.run_rows(&mut a, 2, 1, FORCE, |_r0, w| {
            for v in w.iter_mut() {
                *v += 1;
            }
        });
        let b = other.join().expect("second dispatcher completes");
        assert_eq!(a, vec![1, 1], "first dispatcher corrupted");
        assert_eq!(b, vec![10, 10], "second dispatcher corrupted");
    });
    assert!(report.failure.is_none(), "model failed: {:?}", report.failure);
}

/// Invariant: shutdown is sound against every startup/park
/// interleaving — dropping the pool (with or without a prior dispatch)
/// joins all workers without deadlock, including the schedule where a
/// worker has not yet parked when `shutdown` is raised.
#[test]
fn drop_joins_workers() {
    let report = model().try_check(|| {
        // no dispatch at all: worker may still be before its first park
        let pool = WorkerPool::new(2);
        drop(pool);
    });
    assert!(report.failure.is_none(), "model failed: {:?}", report.failure);
}

/// Invariant (satellite: `kernel_us` accounting races are benign): a
/// concurrent reader of the metrics counter never deadlocks the
/// protocol and observes a monotone value; after the dispatch joins,
/// the dispatcher's contribution is visible to the owner.
#[test]
fn kernel_us_accounting_benign() {
    let report = model().try_check(|| {
        let pool = Arc::new(WorkerPool::new(2));
        let p2 = pool.clone();
        let reader = spawn_named("metrics-reader", move || {
            let a = p2.kernel_us();
            let b = p2.kernel_us();
            assert!(b >= a, "kernel_us went backwards");
        });
        let mut data = vec![0u32; 2];
        pool.run_rows(&mut data, 2, 1, FORCE, |_r0, w| {
            for v in w.iter_mut() {
                *v += 1;
            }
        });
        reader.join().expect("reader completes");
        assert_eq!(data, vec![1, 1]);
    });
    assert!(report.failure.is_none(), "model failed: {:?}", report.failure);
}
