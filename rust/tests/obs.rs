//! Deterministic observability integration suite.
//!
//! Drives a full serve session on the native backend under
//! [`Clock::test`] — every timestamp comes from an auto-advancing
//! deterministic counter, so the recorded span tree is *exactly*
//! reproducible run to run — and asserts the ISSUE acceptance
//! criteria end to end:
//!
//! * the Chrome trace export is valid JSON with correct span nesting
//!   (every per-request span sits inside its request's root span);
//! * histogram percentiles are ordered (p50 ≤ p95 ≤ p99) and bucket
//!   counts sum to the event count, for all three serving histograms;
//! * a domain shift mid-traffic produces at least one [`RequantEvent`]
//!   whose measured drift exceeds the configured threshold, with
//!   per-layer drift scores, per-layer activation-weighted
//!   reconstruction errors and monotone weight generations;
//! * a probed session (`probe_every = 3`) fires the online quality
//!   probe on exactly every third committed plain decode step, with one
//!   `Probe` span per sample nested inside the owning request's root.
//!
//! The traffic mix mirrors `examples/trace_generate.rs`: half the
//! requests from one synthetic corpus domain, half from another, with
//! a tight drift threshold so the shift reliably trips the detector.

use anyhow::Result;
use std::sync::atomic::Ordering::Relaxed;
use ttq_serve::backend::NativeBackend;
use ttq_serve::coordinator::{Server, ServerConfig};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::obs::export::{chrome_trace, chrome_trace_with_profile, prometheus_profile};
use ttq_serve::obs::profile::HostSpec;
use ttq_serve::obs::{Clock, ProfileReport, RequantEvent, SpanKind, TraceEvent, ENGINE_SEQ};
use ttq_serve::util::json::Value;

/// Everything the assertions need, extracted before the server (which
/// borrows the backend) goes out of scope.
struct Session {
    events: Vec<TraceEvent>,
    dropped: u64,
    requants: Vec<RequantEvent>,
    completed: u64,
    decode_steps: u64,
    spec_rounds: u64,
    /// (count, bucket-sum, p50, p95, p99) per histogram:
    /// request latency, decode step, spec round.
    hists: [(u64, u64, f64, f64, f64); 3],
    trace_json: String,
}

const REQUESTS_PER_DOMAIN: usize = 4;

/// One scripted serve session on the deterministic clock: 4 requests
/// from `wt2s`, then 4 from `c4s` (the domain shift that accumulates
/// drift), all plain-decoded to completion.
fn session() -> Result<Session> {
    // Pin the pool to 2 lanes so the big matmuls take the pooled path
    // (and record Kernel spans) even on a single-core CI runner.
    let backend = NativeBackend::new(&ttq_serve::artifacts_dir()).with_threads(2);
    let mut cfg = ServerConfig::new("qwen-micro")
        .with_clock(Clock::test(25))
        .with_trace_capacity(8192)
        .with_max_new_tokens(5);
    // Tight threshold: any real post-commit drift must trigger, so the
    // suite can assert a *finite*-drift requant (the first commit's
    // never-quantized layers report infinite drift).
    cfg.calib.drift_threshold = 1e-4;

    let mut server = Server::new(&backend, cfg)?;
    let prompt_len = server.max_seq() / 2;
    for domain in ["wt2s", "c4s"] {
        let mut stream = CorpusStream::new(domain, Split::Eval);
        for _ in 0..REQUESTS_PER_DOMAIN {
            let mut toks = vec![BOS; prompt_len];
            for t in toks.iter_mut().skip(1) {
                *t = stream.next_token();
            }
            server.submit(toks);
        }
    }
    while server.pending() > 0 || server.running() > 0 {
        server.step()?;
    }

    let m = &server.metrics;
    let hist_of = |h: &ttq_serve::obs::Hist| {
        let sum: u64 = h.nonzero_buckets().iter().map(|b| b.count).sum();
        (h.count(), sum, h.p50(), h.p95(), h.p99())
    };
    let events = server.trace().snapshot();
    Ok(Session {
        trace_json: chrome_trace(&events),
        events,
        dropped: server.trace().dropped(),
        requants: server.requant_events().to_vec(),
        completed: m.completed.load(Relaxed),
        decode_steps: m.decode_steps.load(Relaxed),
        spec_rounds: m.spec_rounds.load(Relaxed),
        hists: [
            hist_of(&m.latency_hist),
            hist_of(&m.decode_step_hist),
            hist_of(&m.spec_round_hist),
        ],
    })
}

#[test]
fn requant_events_capture_drift_introspection() -> Result<()> {
    let s = session()?;
    // First prefill commits never-quantized layers (infinite drift);
    // the wt2s→c4s shift must then fire at least one more.
    assert!(
        s.requants.len() >= 2,
        "expected initial + drift-triggered requants, got {}",
        s.requants.len()
    );
    assert!(
        s.requants[0].max_drift.is_infinite(),
        "first requant covers never-quantized layers"
    );
    assert!(
        s.requants.iter().any(|e| e.max_drift.is_finite() && e.drift_exceeded()),
        "no requant with finite measured drift above threshold"
    );
    for (i, e) in s.requants.iter().enumerate() {
        assert!(e.drift_exceeded(), "requant {i} fired below threshold: {}", e.describe());
        assert_eq!(e.to_version, e.from_version + 1, "generations must step by one");
        assert!(!e.layer_drifts.is_empty(), "per-layer drift scores missing");
        assert!(!e.layer_recon_err.is_empty(), "per-layer recon errors missing");
        assert!(
            e.layer_recon_err.iter().all(|r| r.is_finite() && *r >= 0.0),
            "recon errors must be finite and non-negative: {:?}",
            e.layer_recon_err
        );
        let worst = e.worst_recon_layers(3);
        assert!(worst.windows(2).all(|w| w[0].1 >= w[1].1), "worst layers unsorted");
        assert!(e.tokens_since_last > 0, "requant with no observed evidence");
        assert!(e.quant_us > 0, "deterministic clock must charge quant time");
        let top = e.top_layers(3);
        assert!(top.windows(2).all(|w| w[0].1 >= w[1].1 || w[0].1.is_nan()));
        assert!(e.describe().contains("drift="), "describe() must show drift");
    }
    for w in s.requants.windows(2) {
        assert!(w[1].from_version >= w[0].to_version, "generations regressed");
        assert!(w[1].at_us >= w[0].at_us, "events out of order");
    }
    Ok(())
}

#[test]
fn span_tree_nests_within_request_roots() -> Result<()> {
    let s = session()?;
    assert_eq!(s.dropped, 0, "ring overflowed; grow trace_capacity");
    let roots: Vec<&TraceEvent> = s
        .events
        .iter()
        .filter(|e| e.kind == SpanKind::Request)
        .collect();
    assert_eq!(
        roots.len(),
        2 * REQUESTS_PER_DOMAIN,
        "one root span per completed request"
    );
    for ev in &s.events {
        if ev.seq == ENGINE_SEQ {
            continue;
        }
        let root = roots
            .iter()
            .find(|r| r.seq == ev.seq)
            .unwrap_or_else(|| panic!("span {:?} has no request root", ev.kind));
        assert!(
            ev.start_us >= root.start_us,
            "{:?} starts before its request root",
            ev.kind
        );
        assert!(
            ev.start_us + ev.dur_us <= root.start_us + root.dur_us,
            "{:?} ends after its request root",
            ev.kind
        );
    }
    // The engine track carries requants (old→new generation in the
    // payload), kernel dispatches and cache-occupancy counter samples.
    let requant_spans: Vec<&TraceEvent> = s
        .events
        .iter()
        .filter(|e| e.kind == SpanKind::Requant)
        .collect();
    assert_eq!(requant_spans.len(), s.requants.len());
    for sp in &requant_spans {
        assert_eq!(sp.seq, ENGINE_SEQ, "requants ride the engine track");
        assert_eq!(sp.weight_version, sp.a + 1, "span must carry old→new generation");
    }
    assert!(
        s.events.iter().any(|e| e.kind == SpanKind::Kernel && e.seq == ENGINE_SEQ),
        "pooled kernel dispatches must be spanned"
    );
    assert!(
        s.events.iter().any(|e| e.kind == SpanKind::CacheOccupancy),
        "cache occupancy counter samples missing"
    );
    let steps = s.events.iter().filter(|e| e.kind == SpanKind::DecodeStep).count();
    assert!(steps > 0, "no decode-step spans recorded");
    Ok(())
}

#[test]
fn chrome_trace_export_is_valid_and_complete() -> Result<()> {
    let s = session()?;
    let v = Value::parse(&s.trace_json).expect("exported trace must be valid JSON");
    let arr = v
        .field("traceEvents")
        .expect("top-level traceEvents array")
        .as_arr()
        .expect("traceEvents is an array");
    assert!(!arr.is_empty());
    let mut complete = 0usize;
    let mut counters = 0usize;
    for e in arr {
        let ph = e.field("ph").unwrap().as_str().unwrap();
        match ph {
            "M" => continue, // metadata rows carry no ts
            "X" => {
                complete += 1;
                assert!(e.get("dur").and_then(|d| d.as_f64()).is_some());
            }
            "C" => counters += 1,
            other => panic!("unexpected phase {other:?}"),
        }
        assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
        assert!(e.get("tid").and_then(|t| t.as_f64()).is_some());
    }
    let want_counters = s.events.iter().filter(|e| e.kind.is_counter()).count();
    assert_eq!(counters, want_counters, "every counter sample exports as ph=C");
    assert_eq!(
        complete,
        s.events.len() - want_counters,
        "every span exports as ph=X"
    );
    Ok(())
}

#[test]
fn metrics_percentiles_ordered_and_buckets_sum() -> Result<()> {
    let s = session()?;
    let expect = [
        ("request latency", s.completed),
        ("decode step", s.decode_steps),
        ("spec round", s.spec_rounds),
    ];
    assert_eq!(s.completed, 2 * REQUESTS_PER_DOMAIN as u64);
    assert!(s.decode_steps > 0);
    for ((name, want_count), (count, bucket_sum, p50, p95, p99)) in
        expect.iter().zip(s.hists.iter())
    {
        assert_eq!(count, want_count, "{name}: hist count vs counter");
        assert_eq!(bucket_sum, count, "{name}: bucket counts must sum to count");
        if *count > 0 {
            assert!(p50 <= p95 && p95 <= p99, "{name}: p50 {p50} p95 {p95} p99 {p99}");
            assert!(*p50 > 0.0, "{name}: deterministic clock gives nonzero times");
        } else {
            assert_eq!(*p99, 0.0, "{name}: empty hist reports 0");
        }
    }
    Ok(())
}

#[test]
fn sessions_on_the_same_clock_are_identical() -> Result<()> {
    let a = session()?;
    let b = session()?;
    // Every timestamp and payload is clock- or input-derived except one:
    // DecodeStep's `a` word carries the pool's *measured* kernel time
    // (real wall time by design — R5 exempts the pool's own timing), so
    // it is masked before the bit-identical comparison.
    let normalize = |evs: &[TraceEvent]| -> Vec<TraceEvent> {
        evs.iter()
            .map(|e| {
                let mut e = *e;
                if e.kind == SpanKind::DecodeStep {
                    e.a = 0;
                }
                e
            })
            .collect()
    };
    assert_eq!(
        normalize(&a.events),
        normalize(&b.events),
        "span trees must be identical up to measured kernel time"
    );
    assert_eq!(a.requants.len(), b.requants.len());
    for (x, y) in a.requants.iter().zip(&b.requants) {
        assert_eq!(x.describe(), y.describe());
        assert_eq!(x.layer_drifts, y.layer_drifts);
    }
    assert_eq!(a.hists, b.hists);
    Ok(())
}

/// One profiled serve session (4 plain `wt2s` requests on the
/// deterministic clock): the roofline report against a synthetic host,
/// the recorded trace, and the *peak* KV byte gauges observed while
/// requests were live (the gauges read near zero once every sequence
/// has released its slot).
fn profiled_session(
    trace_capacity: usize,
) -> Result<(ProfileReport, Vec<TraceEvent>, u64, u64)> {
    let backend = NativeBackend::new(&ttq_serve::artifacts_dir()).with_threads(2);
    let cfg = ServerConfig::new("qwen-micro")
        .with_clock(Clock::test(25))
        .with_trace_capacity(trace_capacity)
        .with_max_new_tokens(5)
        .with_profile(true);
    let mut server = Server::new(&backend, cfg)?;
    let prompt_len = server.max_seq() / 2;
    let mut stream = CorpusStream::new("wt2s", Split::Eval);
    for _ in 0..4 {
        let mut toks = vec![BOS; prompt_len];
        for t in toks.iter_mut().skip(1) {
            *t = stream.next_token();
        }
        server.submit(toks);
    }
    let (mut max_occ, mut max_waste) = (0u64, 0u64);
    while server.pending() > 0 || server.running() > 0 {
        server.step()?;
        max_occ = max_occ.max(server.metrics.kv_occupancy_bytes.load(Relaxed));
        max_waste = max_waste.max(server.metrics.kv_waste_bytes.load(Relaxed));
    }
    let rep = server
        .profile_report(&HostSpec::synthetic(10.0, 50.0))
        .expect("profiler attached via with_profile");
    Ok((rep, server.trace().snapshot(), max_occ, max_waste))
}

#[test]
fn profiler_attribution_within_ten_percent() -> Result<()> {
    let (rep, _, _, _) = profiled_session(0)?;
    assert!(rep.kernel_us > 0, "session ran no pooled kernels");
    assert_eq!(rep.dropped, 0, "site table overflowed");
    assert!(!rep.sites.is_empty(), "no kernel sites attributed");
    let cov = rep.coverage();
    assert!(
        (0.9..=1.1).contains(&cov),
        "attributed {} of {} kernel us — coverage {cov:.3} outside [0.9, 1.1]",
        rep.attributed_us,
        rep.kernel_us
    );
    for s in &rep.sites {
        // fp32 serving dispatches dense GEMMs and cached attention only,
        // and the server gauges exactly the prefill/decode phases
        assert!(
            matches!(s.site.kind.name(), "fp32_gemm" | "cached_attention"),
            "unexpected kind in {}",
            s.site.label()
        );
        assert!(
            matches!(s.site.phase.name(), "prefill" | "decode"),
            "unexpected phase in {}",
            s.site.label()
        );
        assert!(s.calls > 0 && s.flops > 0 && s.bytes > 0);
    }
    Ok(())
}

#[test]
fn profiled_sessions_replay_identically() -> Result<()> {
    let (a, _, _, _) = profiled_session(0)?;
    let (b, _, _, _) = profiled_session(0)?;
    // Wall time is real (the pool's own timing is measured, by design);
    // everything input-derived — the site keys, dispatch counts and
    // analytic FLOP/byte totals — must replay bit-identically.
    let keys = |rep: &ProfileReport| {
        let mut v: Vec<_> = rep
            .sites
            .iter()
            .map(|r| (r.site.label(), r.calls, r.flops, r.bytes))
            .collect();
        v.sort();
        v
    };
    assert_eq!(keys(&a), keys(&b), "profiler tables must replay identically");
    Ok(())
}

#[test]
fn kv_byte_telemetry_gauges_and_counter_track() -> Result<()> {
    let (rep, events, max_occ, max_waste) = profiled_session(8192)?;
    assert!(max_occ > 0, "kv occupancy gauge never set");
    assert!(
        max_waste > 0,
        "half-context prompts must leave reserved-but-unused slab bytes"
    );
    let kv: Vec<&TraceEvent> = events
        .iter()
        .filter(|e| e.kind == SpanKind::KvBytes)
        .collect();
    assert!(!kv.is_empty(), "no kv_cache_bytes counter samples recorded");
    assert!(
        kv.iter().all(|e| e.seq == ENGINE_SEQ),
        "kv byte samples ride the engine track"
    );
    assert!(
        kv.iter().any(|e| e.a > 0 && e.b > 0),
        "some sample must observe both occupancy and waste"
    );
    assert!(kv.iter().all(|e| e.kind.is_counter()));

    // Chrome export: the kv samples become a counter track and the
    // profile report becomes its own slice track, all valid JSON.
    let json = chrome_trace_with_profile(&events, Some(&rep));
    let v = Value::parse(&json).expect("exported trace must be valid JSON");
    let arr = v.field("traceEvents").unwrap().as_arr().unwrap();
    assert!(
        arr.iter().any(|e| {
            e.get("name").and_then(|n| n.as_str()) == Some("kv_cache_bytes")
                && e.field("ph").unwrap().as_str() == Some("C")
                && e.field("args").unwrap().get("occupancy_bytes").is_some()
        }),
        "kv counter samples missing from the export"
    );
    assert!(
        arr.iter()
            .any(|e| e.get("cat").and_then(|c| c.as_str()) == Some("profile")),
        "kernel-profile track missing from the export"
    );

    // Prometheus: every site lands in the labelled ttq_kernel_* families.
    let prom = prometheus_profile(&rep);
    assert!(prom.contains("ttq_kernel_calls_total{kind=\""), "{prom}");
    assert!(prom.contains("ttq_kernel_coverage_ratio"), "{prom}");
    Ok(())
}

/// Probe cadence for the probed-session test: with a single plain
/// request the batch has one row, so the rotating row sampler always
/// picks it and the probe must fire on *exactly* every third step.
const PROBE_EVERY: usize = 3;

#[test]
fn probed_session_cadence_and_nesting() -> Result<()> {
    let backend = NativeBackend::new(&ttq_serve::artifacts_dir()).with_threads(2);
    let cfg = ServerConfig::new("qwen-micro")
        .with_clock(Clock::test(25))
        .with_trace_capacity(8192)
        .with_max_new_tokens(7)
        .with_probe_every(PROBE_EVERY);
    let mut server = Server::new(&backend, cfg)?;
    let prompt_len = server.max_seq() / 2;
    let mut stream = CorpusStream::new("wt2s", Split::Eval);
    let mut toks = vec![BOS; prompt_len];
    for t in toks.iter_mut().skip(1) {
        *t = stream.next_token();
    }
    server.submit(toks);
    while server.pending() > 0 || server.running() > 0 {
        server.step()?;
    }

    // deterministic cadence: one sample per every-third committed step
    let decode_steps = server.metrics.decode_steps.load(Relaxed);
    let samples = server.metrics.probe_samples.load(Relaxed);
    assert!(decode_steps >= PROBE_EVERY as u64, "session too short to probe");
    assert_eq!(
        samples,
        decode_steps / PROBE_EVERY as u64,
        "probe must fire on exactly every {PROBE_EVERY}th committed step"
    );
    assert!(samples > 0, "no probe fired; grow max_new_tokens");
    assert_eq!(server.metrics.probe_kl_hist.count(), samples);
    assert_eq!(server.metrics.probe_nll_delta_hist.count(), samples);
    assert!(
        server.metrics.probe_us.load(Relaxed) > 0,
        "deterministic clock must charge probe replay time"
    );
    assert!(server.metrics.summary().contains("probe"), "summary omits probe section");

    // span contract: one Probe span per sample, riding the owning
    // request's track and nested inside its root span
    let events = server.trace().snapshot();
    let probes: Vec<&TraceEvent> = events
        .iter()
        .filter(|e| e.kind == SpanKind::Probe)
        .collect();
    assert_eq!(probes.len() as u64, samples, "one probe span per sample");
    let root = events
        .iter()
        .find(|e| e.kind == SpanKind::Request)
        .expect("request root span");
    for (i, p) in probes.iter().enumerate() {
        assert_eq!(p.seq, root.seq, "probe spans ride the request track");
        assert!(p.start_us >= root.start_us, "probe starts before its root");
        assert!(
            p.start_us + p.dur_us <= root.start_us + root.dur_us,
            "probe span escapes its request root"
        );
        assert!(p.b <= 1, "payload b is the top-1 agreement bit");
        if i > 0 {
            assert!(p.start_us > probes[i - 1].start_us, "probe spans out of order");
        }
    }
    Ok(())
}

#[test]
fn four_phase_kernel_counters_sum_to_pool_time() -> Result<()> {
    // Mixed plain + speculative traffic on the deterministic clock: all
    // four serving phases (prefill, decode, spec-draft, spec-verify)
    // must see kernel time, and the four counters must sum *exactly* to
    // the pool's cumulative kernel time over the session — no phase
    // window may leak or double-count a dispatch. The largest synthetic
    // model keeps every dispatch above the counter's 1 µs granularity.
    let backend = NativeBackend::new(&ttq_serve::artifacts_dir()).with_threads(2);
    let cfg = ServerConfig::new("opt-small")
        .with_clock(Clock::test(25))
        .with_max_new_tokens(4)
        .with_profile(true);
    let mut server = Server::new(&backend, cfg)?;
    let kern0 = backend.pool().kernel_us();
    let prompt_len = server.max_seq() / 2;
    let mut stream = CorpusStream::new("wt2s", Split::Eval);
    for i in 0..4 {
        let mut toks = vec![BOS; prompt_len];
        for t in toks.iter_mut().skip(1) {
            *t = stream.next_token();
        }
        if i % 2 == 0 {
            server.submit(toks);
        } else {
            server.submit_speculative(toks);
        }
    }
    while server.pending() > 0 || server.running() > 0 {
        server.step()?;
    }
    let total = backend.pool().kernel_us() - kern0;
    let m = &server.metrics;
    assert!(m.prefill_kernel_us.load(Relaxed) > 0, "prefill phase unmeasured");
    assert!(m.decode_kernel_us.load(Relaxed) > 0, "decode phase unmeasured");
    assert!(m.spec_draft_kernel_us.load(Relaxed) > 0, "spec-draft phase unmeasured");
    assert!(m.spec_verify_kernel_us.load(Relaxed) > 0, "spec-verify phase unmeasured");
    assert_eq!(
        m.kernel_us_total(),
        total,
        "phase counters (prefill {} + decode {} + draft {} + verify {}) must sum to \
         the pool's kernel time",
        m.prefill_kernel_us.load(Relaxed),
        m.decode_kernel_us.load(Relaxed),
        m.spec_draft_kernel_us.load(Relaxed),
        m.spec_verify_kernel_us.load(Relaxed)
    );
    // the summary line surfaces the split for humans
    let s = m.summary();
    assert!(s.contains("draft") && s.contains("verify"), "{s}");
    Ok(())
}
