//! Cross-language quant validation: the rust quantizers must match the
//! jnp reference oracle bit-for-bit (within f32 tolerance) through the
//! golden vectors emitted by `aot.dump_quant_golden`, plus cross-method
//! behaviour on trained-model statistics.

use std::fs;

use ttq_serve::linalg::Mat;
use ttq_serve::quant::{
    awq_quantize, diag_from_x, lowrank_init, rtn_quantize, QuantSpec, TtqHyper,
    ttq_quantize_lowrank,
};
use ttq_serve::util::json::Value;

fn golden() -> Option<Value> {
    let p = ttq_serve::artifacts_dir().join("golden/quant_golden.json");
    let s = fs::read_to_string(p).ok()?;
    Some(Value::parse(&s).expect("golden parses"))
}

fn mat_from(v: &Value, key: &str, rows: usize, cols: usize) -> Mat {
    let data: Vec<f32> = v
        .field(key)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect();
    Mat::from_vec(rows, cols, data)
}

fn vec_from(v: &Value, key: &str) -> Vec<f32> {
    v.field(key)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

fn assert_close(got: &[f32], want: &[f32], atol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (g, w) in got.iter().zip(want) {
        worst = worst.max((g - w).abs());
    }
    assert!(worst <= atol, "{what}: max abs err {worst} > {atol}");
}

#[test]
fn rtn_matches_jnp_reference_all_cases() {
    let Some(g) = golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let w = mat_from(&g, "w", 8, 64);
    let cases = g.field("cases").unwrap();
    for (q, grp) in [(2u32, 16usize), (3, 32), (4, 32), (5, 64), (4, 128)] {
        let key = format!("q{q}_g{grp}");
        let want = vec_from(cases.field(&key).unwrap(), "rtn");
        let got = rtn_quantize(&w, &QuantSpec::new(q, grp));
        assert_close(&got.data, &want, 1e-5, &format!("rtn {key}"));
    }
}

#[test]
fn awq_matches_jnp_reference_all_cases() {
    let Some(g) = golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let w = mat_from(&g, "w", 8, 64);
    let x = mat_from(&g, "x", 64, 12);
    let cases = g.field("cases").unwrap();
    for (q, grp) in [(2u32, 16usize), (3, 32), (4, 32), (5, 64), (4, 128)] {
        let key = format!("q{q}_g{grp}");
        let want = vec_from(cases.field(&key).unwrap(), "awq");
        let d = diag_from_x(&x, 2.0, 0.4, 0.5);
        let got = awq_quantize(&w, &d, &QuantSpec::new(q, grp));
        assert_close(&got.data, &want, 1e-4, &format!("awq {key}"));
    }
}

#[test]
fn awq_diag_matches_jnp_reference() {
    let Some(g) = golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let x = mat_from(&g, "x", 64, 12);
    let want = vec_from(&g, "awq_diag_p2");
    let got = diag_from_x(&x, 2.0, 0.4, 0.5);
    assert_close(&got, &want, 1e-5, "awq diag p=2");
}

#[test]
fn lowrank_product_matches_jnp_svd() {
    // Different SVD algorithms agree on the *product* BA (unique given
    // distinct singular values), not on the factors themselves.
    let Some(g) = golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let w = mat_from(&g, "w", 8, 64);
    let want = vec_from(&g, "ba");
    let lr = lowrank_init(&w, 4);
    let got = lr.product();
    assert_close(&got.data, &want, 5e-3, "rank-4 BA product");
}

#[test]
fn full_ttq_lowrank_projection_matches_jnp() {
    let Some(g) = golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let w = mat_from(&g, "w", 8, 64);
    let x = mat_from(&g, "x", 64, 12);
    let want = vec_from(&g, "ttq_r4_q3_g32_y");
    let t = ttq_quantize_lowrank(&w, &x, 4, &QuantSpec::new(3, 32), &TtqHyper::default());
    let got = t.weight.matmul(&x);
    // looser: SVD differences flow through the quantizer rounding
    assert_close(&got.data, &want, 0.15, "ttq r=4 projection");
}
