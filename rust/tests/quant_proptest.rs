//! Property-based tests over the quantization stack and coordinator
//! invariants (via the in-tree `propcheck` substrate).

use ttq_serve::coordinator::{Batcher, BatchPolicy, Request};
use ttq_serve::linalg::Mat;
use ttq_serve::prop_assert;
use ttq_serve::quant::{
    awq_quantize, diag_from_x, pack, rtn_dequantize, rtn_quantize,
    rtn_quantize_int, unpack, ActStats, LayerStats, MethodRegistry, MethodSpec,
    QdqFormat, QuantSpec,
};
use ttq_serve::util::propcheck::{check, Config};

fn cfg() -> Config {
    Config { cases: 48, seed: 0xDEC0DE }
}

#[test]
fn prop_rtn_error_bounded_by_half_step() {
    check("rtn |err| <= S/2", &cfg(), |g| {
        let rows = g.usize_in(1, 12);
        let grp = *g.choose(&[8usize, 16, 32, 64]);
        let cols = grp * g.usize_in(1, 4);
        let bits = g.u32_in(2, 8);
        let w = Mat::from_vec(rows, cols, g.vec_f32_adversarial(rows * cols));
        let spec = QuantSpec::new(bits, grp);
        let what = rtn_quantize(&w, &spec);
        let qmax = spec.qmax();
        for (cw, cq) in w.data.chunks(grp).zip(what.data.chunks(grp)) {
            let mx = cw.iter().cloned().fold(f32::MIN, f32::max);
            let mn = cw.iter().cloned().fold(f32::MAX, f32::min);
            let s = ((mx - mn) / qmax).max(0.0);
            for (a, b) in cw.iter().zip(cq) {
                let tol = s / 2.0 + 1e-4 * s.max(1.0);
                prop_assert!(
                    (a - b).abs() <= tol,
                    "err {} > {tol} (bits={bits} g={grp})",
                    (a - b).abs()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rtn_idempotent() {
    check("rtn(rtn(w)) == rtn(w)", &cfg(), |g| {
        let grp = *g.choose(&[16usize, 32]);
        let w = Mat::from_vec(4, grp * 2, g.vec_f32(8 * grp));
        let spec = QuantSpec::new(g.u32_in(2, 6), grp);
        let w1 = rtn_quantize(&w, &spec);
        let w2 = rtn_quantize(&w1, &spec);
        for (a, b) in w1.data.iter().zip(&w2.data) {
            let scale = a.abs().max(1.0);
            prop_assert!((a - b).abs() <= 1e-5 * scale, "{a} vs {b}");
        }
        Ok(())
    });
}

#[test]
fn prop_awq_scaling_invariance() {
    // Ŵ(c·D) == Ŵ(D) for any positive constant c: only *relative*
    // channel scales matter (the paper's α-exponent freedom). Exact in
    // real arithmetic; in f32 an element sitting on a rounding boundary
    // can flip one level, so the property is: almost all elements
    // identical, flips bounded by ~one quantization step.
    check("awq scale invariance", &cfg(), |g| {
        let w = Mat::from_vec(6, 32, g.vec_f32(192));
        let x = Mat::from_vec(32, 9, g.vec_f32(288));
        let d = diag_from_x(&x, 2.0, 0.4, 0.5);
        let c = g.f64_in(0.5, 4.0) as f32;
        let d2: Vec<f32> = d.iter().map(|v| v * c).collect();
        let spec = QuantSpec::new(3, 16);
        let a = awq_quantize(&w, &d, &spec);
        let b = awq_quantize(&w, &d2, &spec);
        // per-group quantization step of the scaled weight
        let scaled: Vec<f32> = w
            .data
            .iter()
            .enumerate()
            .map(|(i, v)| v * d[i % 32])
            .collect();
        let steps: Vec<f32> = scaled
            .chunks(16)
            .map(|grp| {
                let mx = grp.iter().cloned().fold(f32::MIN, f32::max);
                let mn = grp.iter().cloned().fold(f32::MAX, f32::min);
                (mx - mn) / 7.0
            })
            .collect();
        let mut flips = 0usize;
        for (i, (u, v)) in a.data.iter().zip(&b.data).enumerate() {
            let diff = (u - v).abs();
            if diff <= 1e-3 * u.abs().max(0.1) {
                continue;
            }
            // boundary flip: bounded by ~one step, descaled by D
            let tol = 1.2 * steps[i / 16] / d[i % 32];
            prop_assert!(diff <= tol, "{u} vs {v} (c={c}, diff {diff} > {tol})");
            flips += 1;
        }
        prop_assert!(
            flips * 50 <= a.data.len(),
            "{flips}/{} elements flipped (c={c}) — not scale invariant",
            a.data.len()
        );
        Ok(())
    });
}

#[test]
fn prop_pack_roundtrip() {
    check("pack/unpack identity", &cfg(), |g| {
        let bits = g.u32_in(2, 8);
        let grp = *g.choose(&[16usize, 32]);
        let rows = g.usize_in(1, 8);
        let w = Mat::from_vec(rows, grp * 2, g.vec_f32(rows * grp * 2));
        let qi = rtn_quantize_int(&w, &QuantSpec::new(bits, grp));
        let p = pack(&qi);
        prop_assert!(unpack(&p) == qi.codes, "roundtrip mismatch bits={bits}");
        // dense packing: words * 32 bits within one word of n*bits
        let need = (qi.codes.len() * bits as usize).div_ceil(32);
        prop_assert!(p.words.len() == need, "padding leak");
        Ok(())
    });
}

#[test]
fn prop_int_dequant_matches_qdq() {
    check("int path == qdq path", &cfg(), |g| {
        let grp = *g.choose(&[16usize, 32, 64]);
        let w = Mat::from_vec(4, grp, g.vec_f32_adversarial(4 * grp));
        let spec = QuantSpec::new(g.u32_in(2, 8), grp);
        let direct = rtn_quantize(&w, &spec);
        let via_int = rtn_dequantize(&rtn_quantize_int(&w, &spec));
        for (a, b) in direct.data.iter().zip(&via_int.data) {
            prop_assert!((a - b).abs() <= 1e-5 * a.abs().max(1.0), "{a} vs {b}");
        }
        Ok(())
    });
}

#[test]
fn prop_formats_all_produce_valid_qdq() {
    check("formats stay bounded", &cfg(), |g| {
        let w = Mat::from_vec(4, 64, g.vec_f32_adversarial(256));
        let fmt = *g.choose(&[
            QdqFormat::Asymmetric,
            QdqFormat::Symmetric,
            QdqFormat::Expanded { nu: 0.95 },
        ]);
        let spec = QuantSpec { bits: g.u32_in(2, 5), group: 32, format: fmt };
        let q = rtn_quantize(&w, &spec);
        let wmax = w.max_abs();
        for v in &q.data {
            prop_assert!(v.is_finite(), "non-finite output");
            prop_assert!(v.abs() <= 2.5 * wmax + 1.0, "runaway value {v}");
        }
        Ok(())
    });
}

// ------------------------------------------------------------------
// Method registry invariants
// ------------------------------------------------------------------

#[test]
fn registry_examples_roundtrip() {
    // every registered method: example parses, canonical spec string
    // re-parses to an equal method with a stable label
    for entry in MethodRegistry::global().entries() {
        let m = MethodSpec::parse(entry.example)
            .unwrap_or_else(|e| panic!("example '{}' must parse: {e}", entry.example));
        assert_eq!(m.quantizer().name(), entry.name);
        assert!(!m.label().is_empty(), "{}: empty label", entry.name);
        let canon = m.spec_string();
        let again = MethodSpec::parse(&canon)
            .unwrap_or_else(|e| panic!("canonical '{canon}' must re-parse: {e}"));
        assert_eq!(m, again, "round-trip of '{}' via '{canon}'", entry.example);
        assert_eq!(m.label(), again.label(), "label drift through '{canon}'");
    }
}

#[test]
fn prop_registered_quantizers_bounded_reconstruction() {
    // Every registered method, fed the statistics its StatsRequirement
    // names, must produce shape-preserving finite output. The plain-QDQ
    // methods additionally satisfy their QdqFormat reconstruction
    // bounds exactly (asymmetric |err| <= S/2 for RTN, the absmax
    // envelope for NF); diagonal-scaled and error-fed methods get a
    // generous envelope (they redistribute, not amplify, error).
    check("registry outputs bounded", &cfg(), |g| {
        let grp = *g.choose(&[16usize, 32]);
        let rows = g.usize_in(8, 16);
        let cols = grp * 2;
        let t = cols + 16; // T > d keeps the GPTQ correlation well-posed
        let w = Mat::from_vec(rows, cols, g.vec_f32(rows * cols));
        let x = Mat::from_vec(cols, t, g.vec_f32(cols * t));
        let ps = [0.5f64, 1.0, 2.0, 4.0];
        let mut act = ActStats::new(&ps, cols);
        let sums: Vec<Vec<f64>> = ps
            .iter()
            .map(|&p| {
                (0..cols)
                    .map(|i| x.row(i).iter().map(|&v| (v as f64).abs().powf(p)).sum())
                    .collect()
            })
            .collect();
        act.accumulate(&sums, t as f64);
        let corr = x.matmul_bt(&x);
        let spec = QuantSpec::new(g.u32_in(2, 5), grp);
        let wmax = w.max_abs();

        for entry in MethodRegistry::global().entries() {
            let m = MethodSpec::parse(entry.example).expect("example parses");
            let stats = LayerStats { act: Some(&act), corr: Some(&corr), ..Default::default() };
            let wq = m
                .quantizer()
                .quantize(&w, &stats, &spec)
                .map_err(|e| format!("{}: quantize failed: {e}", entry.name))?;
            prop_assert!(
                wq.rows == w.rows && wq.cols == w.cols,
                "{}: shape {}x{}",
                entry.name,
                wq.rows,
                wq.cols
            );
            for v in &wq.data {
                prop_assert!(v.is_finite(), "{}: non-finite output", entry.name);
            }
            match entry.name {
                "fp" => prop_assert!(wq.data == w.data, "fp must be the identity"),
                "rtn" => {
                    let qmax = spec.qmax();
                    for (cw, cq) in w.data.chunks(grp).zip(wq.data.chunks(grp)) {
                        let mx = cw.iter().cloned().fold(f32::MIN, f32::max);
                        let mn = cw.iter().cloned().fold(f32::MAX, f32::min);
                        let s = ((mx - mn) / qmax).max(0.0);
                        for (a, b) in cw.iter().zip(cq) {
                            prop_assert!(
                                (a - b).abs() <= s / 2.0 + 1e-4 * s.max(1.0),
                                "rtn err {} > S/2 = {}",
                                (a - b).abs(),
                                s / 2.0
                            );
                        }
                    }
                }
                "nf" => {
                    for (cw, cq) in w.data.chunks(grp).zip(wq.data.chunks(grp)) {
                        let amax = cw.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                        for b in cq {
                            prop_assert!(
                                b.abs() <= amax * (1.0 + 1e-5) + 1e-6,
                                "nf value {b} outside absmax envelope {amax}"
                            );
                        }
                    }
                }
                _ => {
                    for v in &wq.data {
                        prop_assert!(
                            v.abs() <= 16.0 * wmax + 1.0,
                            "{}: runaway value {v} (wmax {wmax})",
                            entry.name
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------------
// Coordinator invariants
// ------------------------------------------------------------------

#[test]
fn prop_batcher_conserves_and_orders_requests() {
    check("batcher conservation + FIFO", &cfg(), |g| {
        let buckets = match g.usize_in(0, 2) {
            0 => vec![1usize, 4],
            1 => vec![1usize, 2, 8],
            _ => vec![4usize],
        };
        let n = g.usize_in(1, 40);
        let mut b = Batcher::new(BatchPolicy {
            buckets: buckets.clone(),
            linger: std::time::Duration::ZERO,
        });
        for id in 0..n as u64 {
            b.push(Request::new(id, vec![0; 4], 0));
        }
        let mut seen = Vec::new();
        let far = 1_000_000u64; // 1s after every arrival — linger expired
        let mut guard = 0;
        while b.pending() > 0 {
            guard += 1;
            prop_assert!(guard < 1000, "batcher livelock");
            if let Some(batch) = b.poll(far) {
                prop_assert!(
                    buckets.contains(&batch.bucket),
                    "illegal bucket {}",
                    batch.bucket
                );
                prop_assert!(
                    batch.requests.len() <= batch.bucket,
                    "overfull batch"
                );
                seen.extend(batch.requests.iter().map(|r| r.id));
            }
        }
        prop_assert!(seen.len() == n, "lost requests: {} of {n}", seen.len());
        let sorted: Vec<u64> = (0..n as u64).collect();
        prop_assert!(seen == sorted, "FIFO violated: {seen:?}");
        Ok(())
    });
}

#[test]
fn prop_batcher_never_fires_early() {
    check("no fire before linger", &cfg(), |g| {
        let linger = std::time::Duration::from_millis(g.usize_in(50, 500) as u64);
        let mut b = Batcher::new(BatchPolicy { buckets: vec![1, 4], linger });
        let n = g.usize_in(1, 3); // below max bucket
        for id in 0..n as u64 {
            b.push(Request::new(id, vec![0; 4], 0));
        }
        prop_assert!(
            b.poll(0).is_none(),
            "fired {n} requests before linger"
        );
        Ok(())
    });
}
