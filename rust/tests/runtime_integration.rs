//! Backend integration: execute forwards and validate numerics against
//! model invariants. The structural tests run on whichever backend is
//! available (PJRT over real artifacts, else the native backend over
//! synthetic weights); PJRT-specific artifact-cache tests and trained-
//! model bounds skip gracefully without `make artifacts`.

use ttq_serve::backend::{ExecBackend, NativeBackend, PjrtBackend};
use ttq_serve::corpus::{CorpusStream, Split};
use ttq_serve::eval::Evaluator;
use ttq_serve::runtime::{ArtifactKey, Runtime};

fn backend() -> Box<dyn ExecBackend> {
    if ttq_serve::artifacts_ready() {
        let rt = Runtime::new(&ttq_serve::artifacts_dir()).expect("PJRT client");
        Box::new(PjrtBackend::new(rt))
    } else {
        Box::new(NativeBackend::new(&ttq_serve::artifacts_dir()))
    }
}

fn trained() -> bool {
    ttq_serve::artifacts_ready()
}

#[test]
fn nll_executes_and_is_finite() {
    let be = backend();
    let ev = Evaluator::new(be.as_ref(), "qwen-micro").unwrap();
    let seq = ev.weights.manifest.config.seq;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let toks = s.batch(1, seq);
    let (nll, count) = ev.nll(&toks, 1).unwrap();
    assert!(nll.is_finite() && nll > 0.0, "nll {nll}");
    assert_eq!(count as usize, seq - 1);
    if trained() {
        // a trained model beats the uniform bound log(512) ≈ 6.24
        assert!(nll / count < 6.0, "per-token nll {}", nll / count);
    } else {
        // synthetic weights sit near the uniform bound, not above 2x
        assert!(nll / count < 2.0 * (512f64).ln(), "per-token nll {}", nll / count);
    }
}

#[test]
fn executable_cache_compiles_once() {
    if !trained() {
        eprintln!("skipping: PJRT artifact cache needs `make artifacts`");
        return;
    }
    let rt = Runtime::new(&ttq_serve::artifacts_dir()).expect("PJRT client");
    let key = ArtifactKey::new("opt-micro", "nll", 1);
    let a = rt.load(&key).unwrap();
    let n = rt.compiled_count();
    let b = rt.load(&key).unwrap();
    assert_eq!(rt.compiled_count(), n, "cache miss on identical key");
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[test]
fn stats_pass_matches_manifest_arity() {
    let be = backend();
    let ev = Evaluator::new(be.as_ref(), "opt-micro").unwrap();
    let seq = ev.weights.manifest.config.seq;
    let mut s = CorpusStream::new("ptbs", Split::Eval);
    let toks = s.batch(4, seq);
    let collected = ev.collect(&toks, 4, false).unwrap();
    assert_eq!(collected.stats.len(), ev.weights.manifest.linears.len());
    for (st, lin) in collected.stats.iter().zip(&ev.weights.manifest.linears) {
        assert_eq!(st.d_in(), lin.d_in);
        // norm sums are nonnegative and mostly positive
        assert!(st.norm_sums[2].iter().all(|&v| v >= 0.0));
        assert!(st.norm_sums[2].iter().sum::<f64>() > 0.0);
    }
}

#[test]
fn corr_pass_returns_psd_gram_matrices() {
    let be = backend();
    let ev = Evaluator::new(be.as_ref(), "qwen-micro").unwrap();
    let seq = ev.weights.manifest.config.seq;
    let mut s = CorpusStream::new("c4s", Split::Eval);
    let toks = s.batch(4, seq);
    let collected = ev.collect(&toks, 4, true).unwrap();
    assert_eq!(collected.corr.len(), ev.weights.manifest.linears.len());
    for (c, st) in collected.corr.iter().zip(&collected.stats) {
        assert_eq!(c.rows, c.cols);
        // symmetry + trace == Σ‖x‖² (norms p=2 row)
        let mut tr = 0.0f64;
        for i in 0..c.rows {
            tr += c.at(i, i) as f64;
            assert!(c.at(i, i) >= -1e-3);
            for j in 0..c.cols {
                assert!((c.at(i, j) - c.at(j, i)).abs() < 2e-2);
            }
        }
        let p2: f64 = st.norm_sums[2].iter().sum();
        assert!(
            (tr - p2).abs() / p2.max(1.0) < 1e-3,
            "trace {tr} vs Σ|x|² {p2}"
        );
    }
}

#[test]
fn fused_ttq_close_to_two_pass_pipeline() {
    // The fused kernel (single-pass, per-batch D) and the rust two-pass
    // path implement the same math; per-token NLL must agree. The fused
    // path sees each layer's *quantized-prefix* activations while the
    // two-pass D comes from the fp forward, so the tolerance is looser
    // on untrained synthetic weights (flatter activation profiles).
    let be = backend();
    let mut ev = Evaluator::new(be.as_ref(), "qwen-micro").unwrap();
    let seq = ev.weights.manifest.config.seq;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let toks = s.batch(4, seq);
    let (fused_nll, c1) = ev.nll_fused_ttq(&toks, 4, 3).unwrap();

    let collected = ev.collect(&toks, 4, false).unwrap();
    ev.apply_quantization(
        &ttq_serve::eval::MethodSpec::ttq(0),
        Some(&collected),
        &ttq_serve::eval::EvalConfig {
            spec: ttq_serve::quant::QuantSpec::new(3, 32),
            ..Default::default()
        },
    )
    .unwrap();
    let (two_pass_nll, c2) = ev.nll(&toks, 4).unwrap();
    ev.restore();
    assert_eq!(c1, c2);
    let a = fused_nll / c1;
    let b = two_pass_nll / c2;
    let tol = if trained() { 0.05 } else { 0.25 };
    assert!(
        (a - b).abs() < tol,
        "fused {a} vs two-pass {b} per-token nll"
    );
}

#[test]
fn logits_shape_and_finiteness() {
    let be = backend();
    let ev = Evaluator::new(be.as_ref(), "gemma-micro").unwrap();
    let man = &ev.weights.manifest;
    let (seq, vocab) = (man.config.seq, man.config.vocab);
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let toks = s.batch(1, seq);
    let logits = be.logits(&ev.weights, &toks, 1).unwrap();
    assert_eq!(logits.len(), seq * vocab);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn standalone_kernel_artifact_loads() {
    if !trained() {
        eprintln!("skipping: kernel artifact needs `make artifacts`");
        return;
    }
    let rt = Runtime::new(&ttq_serve::artifacts_dir()).expect("PJRT client");
    let exe = rt.load_path_rel("kernels/ttq_linear.hlo.txt");
    assert!(
        exe.is_ok(),
        "fused kernel artifact must compile: {:?}",
        exe.err()
    );
}

#[test]
fn all_models_load_and_report_params() {
    let be = backend();
    for name in ttq_serve::models::MODEL_NAMES {
        let ev = Evaluator::new(be.as_ref(), name).unwrap();
        assert!(ev.weights.param_count() > 10_000, "{name} too small");
        assert!(!ev.weights.manifest.linears.is_empty());
    }
}
