//! Differential scalar-vs-SIMD harness for the `linalg::simd` inner
//! kernels, driven through the real pooled entry points
//! (`backend::native::{matmul_bt_mt, packed_matmul_nt}`).
//!
//! The contract under test (docs/ARCHITECTURE.md § Kernel dispatch &
//! numerics):
//!
//! * **W4 packed matmul is bit-exact across ISAs** — a vector-selected
//!   pool and a forced-scalar pool produce identical bits for every
//!   shape, bit width and group layout.
//! * **fp32 GEMM/GEMV agrees within the documented ULP bound** —
//!   `util::FP32_MAX_ULPS` / `util::FP32_ABS_TOL`, via the shared
//!   `util::fp32_close` predicate.
//!
//! Shapes are adversarial on purpose: `m = 1` decode GEMVs, dims not
//! divisible by any lane width, `K_TILE = 256` boundaries (255/256/257
//! and 511/512/513), single-group and flat-group W4 layouts, and the
//! projection dims of all three synthetic model families.
//!
//! On a host without AVX2/NEON (or under `TTQ_FORCE_SCALAR=1`) the
//! selected ISA *is* scalar, so the differential pairs collapse to
//! scalar-vs-scalar — the suite then degenerates to an exactness
//! regression harness rather than silently passing nothing: every
//! kernel still runs through the same dispatch, tiling and pool paths.

use ttq_serve::backend::native::{matmul_bt_mt, packed_matmul_nt};
use ttq_serve::linalg::pool::WorkerPool;
use ttq_serve::linalg::simd::{force_scalar, select, Isa};
use ttq_serve::linalg::{Mat, Rng};
use ttq_serve::prop_assert;
use ttq_serve::quant::{pack, rtn_quantize_int, unpack_at, QuantSpec};
use ttq_serve::util::propcheck::{check, Config};
use ttq_serve::util::{assert_fp32_slices_close, fp32_close, max_ulp_diff, FP32_MAX_ULPS};

/// One scalar-reference pool and one selected-ISA pool, same lane
/// count, so any output divergence is the instruction-level dispatch
/// and nothing else.
fn pool_pair(threads: usize) -> (WorkerPool, WorkerPool) {
    (WorkerPool::new_with_isa(threads, Isa::Scalar), WorkerPool::new(threads))
}

fn assert_bits_equal(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: index {i}: {x} vs {y} must be bit-identical"
        );
    }
}

/// Adversarial fp32 shapes: decode GEMVs, non-lane-multiple dims,
/// K_TILE boundaries, and the (d_model, d_mlp) projections of the
/// opt / qwen / gemma synthetic families (testmodel::CONFIGS).
const FP32_SHAPES: &[(usize, usize, usize)] = &[
    // m, k (d_in), n (d_out)
    (1, 64, 512),   // decode GEMV, lane-aligned
    (1, 300, 700),  // GEMV, k % 8 == 4
    (1, 17, 3),     // tiny everything, all tails
    (3, 64, 512),   // small batch
    (7, 300, 129),  // nothing divisible by 8
    (64, 257, 96),  // prefill-ish, k just past K_TILE
    (1, 255, 33),   // K_TILE - 1
    (2, 256, 31),   // K_TILE exactly
    (2, 257, 31),   // K_TILE + 1
    (1, 511, 9),    // 2·K_TILE - 1
    (1, 512, 9),    // 2·K_TILE
    (1, 513, 9),    // 2·K_TILE + 1
    (1, 64, 256),   // opt-micro d_model → d_mlp
    (4, 64, 192),   // qwen-micro d_model → d_mlp
    (2, 256, 64),   // gemma-micro d_mlp → d_model
    (5, 128, 384),  // qwen-mini
    (1, 192, 768),  // opt-small
];

#[test]
fn fp32_matmul_within_ulp_bound_of_scalar() {
    let (scalar, vector) = pool_pair(4);
    let mut rng = Rng::new(0x51D0);
    for &(m, k, n) in FP32_SHAPES {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(n, k, &mut rng);
        let want = matmul_bt_mt(&a, &b, &scalar);
        let got = matmul_bt_mt(&a, &b, &vector);
        assert_eq!((got.rows, got.cols), (want.rows, want.cols));
        assert_fp32_slices_close(
            &got.data,
            &want.data,
            &format!("fp32 matmul m={m} k={k} n={n} ({})", vector.isa().name()),
        );
        let ulps = max_ulp_diff(&got.data, &want.data);
        let all_close = got.data.iter().zip(&want.data).all(|(&x, &y)| fp32_close(x, y));
        assert!(
            ulps <= FP32_MAX_ULPS || all_close,
            "m={m} k={k} n={n}: worst divergence {ulps} ulps"
        );
    }
}

#[test]
fn fp32_scalar_pool_is_bit_stable() {
    // The scalar path is the historical strictly-sequential kernel:
    // two forced-scalar pools (different thread counts — the pool's
    // determinism contract) must agree bit for bit, so forced-scalar
    // serving output is byte-identical to every pre-SIMD release.
    let p1 = WorkerPool::new_with_isa(1, Isa::Scalar);
    let p4 = WorkerPool::new_with_isa(4, Isa::Scalar);
    let mut rng = Rng::new(0x5EED);
    for &(m, k, n) in &[(1usize, 300usize, 129usize), (5, 257, 64), (2, 512, 33)] {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(n, k, &mut rng);
        assert_bits_equal(
            &matmul_bt_mt(&a, &b, &p1),
            &matmul_bt_mt(&a, &b, &p4),
            &format!("scalar fp32 m={m} k={k} n={n}"),
        );
    }
}

#[test]
fn packed_matmul_bit_exact_across_isa() {
    let (scalar, vector) = pool_pair(4);
    let mut rng = Rng::new(0x0004);
    // (d_out, d_in, group): aligned groups, group % 8 != 0 (vector
    // unpack must fall back yet stay exact), and single-group rows.
    let layouts: &[(usize, usize, usize)] = &[
        (33, 64, 16),  // odd d_out, several groups
        (7, 96, 48),   // group % 8 == 0 but not a power of two
        (16, 64, 64),  // single group per row (group == d_in)
        (5, 36, 12),   // group % 8 == 4: scalar unpack path on all ISAs
        (64, 192, 16), // qwen-micro MLP width
    ];
    for &(d_out, d_in, group) in layouts {
        for bits in [2u32, 3, 4, 5, 8] {
            let w = Mat::randn(d_out, d_in, &mut rng);
            let p = pack(&rtn_quantize_int(&w, &QuantSpec::new(bits, group)));
            for n in [1usize, 5] {
                let x = Mat::randn(n, d_in, &mut rng);
                let want = packed_matmul_nt(&p, &x, &scalar);
                let got = packed_matmul_nt(&p, &x, &vector);
                assert_bits_equal(
                    &got,
                    &want,
                    &format!(
                        "packed bits={bits} g={group} d_out={d_out} d_in={d_in} n={n} ({})",
                        vector.isa().name()
                    ),
                );
            }
        }
    }
}

#[test]
fn packed_flat_group_fallback_bit_exact() {
    // d_in % group != 0 routes both pools through the flat-group
    // general kernel — the fallback must stay on the exact contract.
    let (scalar, vector) = pool_pair(2);
    let mut rng = Rng::new(0xF1A7);
    let w = Mat::randn(6, 24, &mut rng);
    let p = pack(&rtn_quantize_int(&w, &QuantSpec::new(4, 48)));
    let x = Mat::randn(3, 24, &mut rng);
    assert_bits_equal(
        &packed_matmul_nt(&p, &x, &scalar),
        &packed_matmul_nt(&p, &x, &vector),
        "flat-group fallback",
    );
}

#[test]
fn packed_matmul_matches_explicit_dequant_reference() {
    // Ground truth independent of linalg::simd entirely: dequantize
    // with unpack_at and reduce with a plain sequential dot, then
    // compare within the documented fp32 tolerance (the canonical-lane
    // W4 order re-associates relative to a sequential sum, so this is
    // a closeness check; scalar-vs-vector exactness is asserted above).
    let (_, vector) = pool_pair(2);
    let mut rng = Rng::new(0xDE0A);
    let (d_out, d_in, group) = (9, 64, 16);
    for bits in [2u32, 4, 8] {
        let w = Mat::randn(d_out, d_in, &mut rng);
        let p = pack(&rtn_quantize_int(&w, &QuantSpec::new(bits, group)));
        let x = Mat::randn(2, d_in, &mut rng);
        let y = packed_matmul_nt(&p, &x, &vector);
        for t in 0..x.rows {
            for r in 0..d_out {
                let mut want = 0.0f32;
                for j in 0..d_in {
                    let gi = r * (d_in / group) + j / group;
                    let wj = unpack_at(&p, r * d_in + j) as f32 * p.scales[gi] + p.zeros[gi];
                    want += wj * x.row(t)[j];
                }
                let got = y.row(t)[r];
                assert!(
                    fp32_close(got, want),
                    "bits={bits} t={t} r={r}: {got} vs reference {want}"
                );
            }
        }
    }
}

#[test]
fn prop_random_shapes_hold_the_contract() {
    let (scalar, vector) = pool_pair(3);
    check(
        "simd differential (fp32 ulp-bounded, W4 bit-exact)",
        &Config { cases: 40, seed: 0x51DD1FF },
        |g| {
            let mut rng = Rng::new(g.usize_in(1, 1 << 30) as u64);
            // fp32: any shape at all
            let (m, k, n) = (g.usize_in(1, 9), g.usize_in(1, 600), g.usize_in(1, 80));
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(n, k, &mut rng);
            let want = matmul_bt_mt(&a, &b, &scalar);
            let got = matmul_bt_mt(&a, &b, &vector);
            for (i, (&x, &y)) in got.data.iter().zip(&want.data).enumerate() {
                prop_assert!(
                    fp32_close(x, y),
                    "fp32 m={m} k={k} n={n} idx={i}: {x} vs {y}"
                );
            }
            // W4: group must divide d_in for the grouped kernel
            let group = *g.choose(&[8usize, 16, 24, 32]);
            let d_in = group * g.usize_in(1, 6);
            let d_out = g.usize_in(1, 40);
            let bits = g.u32_in(2, 8);
            let w = Mat::randn(d_out, d_in, &mut rng);
            let p = pack(&rtn_quantize_int(&w, &QuantSpec::new(bits, group)));
            let x = Mat::randn(g.usize_in(1, 4), d_in, &mut rng);
            let pw = packed_matmul_nt(&p, &x, &scalar);
            let pv = packed_matmul_nt(&p, &x, &vector);
            for (i, (x0, y0)) in pw.data.iter().zip(&pv.data).enumerate() {
                prop_assert!(
                    x0.to_bits() == y0.to_bits(),
                    "W4 bits={bits} g={group} d_out={d_out} d_in={d_in} idx={i}: {x0} vs {y0}"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn selected_isa_is_runnable_and_scalar_when_forced() {
    let selected = select();
    assert!(selected.available(), "select() returned an unrunnable ISA");
    if force_scalar() {
        assert_eq!(selected, Isa::Scalar, "TTQ_FORCE_SCALAR must pin scalar");
    }
    // The pool inherits the selection and never exceeds it.
    let pool = WorkerPool::new(2);
    assert_eq!(pool.isa(), selected);
    // An explicit unavailable request demotes instead of trusting the
    // caller (the unsafe-dispatch safety gate).
    for isa in [Isa::Avx2, Isa::Neon] {
        let p = WorkerPool::new_with_isa(1, isa);
        assert!(p.isa().available());
    }
}

#[test]
fn detection_smoke_matches_ci_expectation() {
    // CI's vector-selected job exports TTQ_EXPECT_ISA=avx2 on x86
    // runners: the job fails loudly if runtime detection silently fell
    // back to scalar (a dead vector path would otherwise pass every
    // differential test). Unset locally → nothing to assert.
    match std::env::var("TTQ_EXPECT_ISA") {
        Ok(want) if !want.is_empty() => {
            assert_eq!(
                select().name(),
                want,
                "host selected `{}` but CI expected `{want}`",
                select().name()
            );
        }
        _ => {}
    }
}
