//! Speculative-decoding correctness suite — runs with ZERO artifacts.
//!
//! The acceptance contract, on every synthetic model family:
//!
//! * `KvCache::truncate` then re-append/advance is **bit-identical** to
//!   never having appended (fp32 and packed-W4 execution) — same shape,
//!   same kernels, so no numerics relaxation applies;
//! * `verify_step` rows match `decode_step` within the documented fp32
//!   kernel contract (`util::FP32_MAX_ULPS` / `util::FP32_ABS_TOL` —
//!   PR 10 relaxed these cross-shape comparisons from bit-identity;
//!   same-ISA in-process they still agree exactly), and the token
//!   streams built on them stay exact (batched verification *is* plain
//!   decode);
//! * speculative greedy generation (W4 drafter × fp32 verifier) is
//!   token-identical to plain greedy generation — and stays identical
//!   under a seeded stochastic sampler, because acceptance is defined
//!   as "draft equals what the sampler draws from the verifier";
//! * the serving integration: speculative requests stream fp32-exact
//!   tokens even while drift-triggered requantization swaps the drafter
//!   mid-generation, plain and speculative requests coexist, and
//!   `ServeEvent::Done` reports why each generation stopped.

use std::time::Duration;

use ttq_serve::backend::{testmodel, ExecBackend, NativeBackend};
use ttq_serve::coordinator::{BatchPolicy, ServeEvent, Server, ServerConfig, StopReason};
use ttq_serve::corpus::{CorpusStream, Split, BOS};
use ttq_serve::eval::{Evaluator, Sampler};
use ttq_serve::kvcache::{KvCache, KvCacheConfig};
use ttq_serve::quant::QuantSpec;
use ttq_serve::specdec::{drafter_weights, SpecConfig, SpecGenerator, SpecModel};
use ttq_serve::util::{argmax, assert_fp32_slices_close};

const FAMILIES: [&str; 3] = ["opt-micro", "qwen-micro", "gemma-micro"];

fn native() -> NativeBackend {
    NativeBackend::new(&ttq_serve::artifacts_dir())
}

fn native_w4() -> NativeBackend {
    native().with_exec_quant(QuantSpec::new(4, 32))
}

fn prompt(stream: &mut CorpusStream, len: usize) -> Vec<i32> {
    let mut toks = vec![BOS; len];
    for t in toks.iter_mut().skip(1) {
        *t = stream.next_token();
    }
    toks
}

// ---------------------------------------------------------------------
// truncate: rollback is bit-identical to never having appended
// ---------------------------------------------------------------------

fn assert_truncate_roundtrip(model: &str, be: &NativeBackend) {
    let w = testmodel::build(model).unwrap();
    let vocab = w.manifest.config.vocab;
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let p = prompt(&mut s, w.manifest.config.max_seq / 2);
    let mut cache = KvCache::new(KvCacheConfig::from_manifest(&w.manifest, 1));
    let id = cache.alloc().unwrap();
    let step = be.prefill(&w, &p, &mut cache, &[id], false).unwrap();
    let base_len = cache.len(id);
    let tok = argmax(&step.logits) as i32;

    // reference: one decode step from the pristine prefill state
    let first = be.decode_step(&w, &[tok], &mut cache, &[id], false).unwrap();
    let next = argmax(&first.logits) as i32;

    // rollback, then re-append the same token: bit-identical logits
    cache.truncate(id, base_len).unwrap();
    let again = be.decode_step(&w, &[tok], &mut cache, &[id], false).unwrap();
    assert_eq!(
        first.logits, again.logits,
        "{model}: truncate+re-append diverged from the original append"
    );

    // deeper: a 3-token verify window, rolled all the way back, must
    // leave the sequence exactly where it started
    cache.truncate(id, base_len).unwrap();
    let v = be
        .verify_step(&w, &[tok, next, next], &mut cache, &[id], false)
        .unwrap();
    assert_eq!(cache.len(id), base_len + 3);
    // cross-shape fp32 comparison (m=3 verify vs m=1 decode): the
    // documented ULP/abs bound, not bit-identity (PR 10).
    assert_fp32_slices_close(
        &v.logits[..vocab],
        &first.logits,
        &format!("{model}: verify_step row 0 vs decode_step"),
    );
    cache.truncate(id, base_len).unwrap();
    let rewound = be.decode_step(&w, &[tok], &mut cache, &[id], false).unwrap();
    assert_eq!(
        first.logits, rewound.logits,
        "{model}: rollback across a verify window is not bit-identical"
    );
}

#[test]
fn truncate_reappend_bit_identical_fp32_all_families() {
    let be = native();
    for model in FAMILIES {
        assert_truncate_roundtrip(model, &be);
    }
}

#[test]
fn truncate_reappend_bit_identical_w4_all_families() {
    let be = native_w4();
    for model in FAMILIES {
        assert_truncate_roundtrip(model, &be);
    }
}

#[test]
fn verify_step_matches_sequential_decode_positions() {
    // all k rows of one verify forward equal k sequential decode steps
    let be = native();
    let w = testmodel::build("qwen-micro").unwrap();
    let vocab = w.manifest.config.vocab;
    let mut s = CorpusStream::new("c4s", Split::Eval);
    let p = prompt(&mut s, 20);

    let mut seq_cache = KvCache::new(KvCacheConfig::from_manifest(&w.manifest, 1));
    let sid = seq_cache.alloc().unwrap();
    let step = be.prefill(&w, &p, &mut seq_cache, &[sid], false).unwrap();
    let mut tok = argmax(&step.logits) as i32;
    let mut window = vec![tok];
    let mut want = Vec::new();
    for _ in 0..4 {
        let out = be.decode_step(&w, &[tok], &mut seq_cache, &[sid], false).unwrap();
        want.extend_from_slice(&out.logits);
        tok = argmax(&out.logits) as i32;
        window.push(tok);
    }
    window.pop(); // the last sampled token was never fed back

    let mut ver_cache = KvCache::new(KvCacheConfig::from_manifest(&w.manifest, 1));
    let vid = ver_cache.alloc().unwrap();
    be.prefill(&w, &p, &mut ver_cache, &[vid], false).unwrap();
    let v = be
        .verify_step(&w, &window, &mut ver_cache, &[vid], false)
        .unwrap();
    assert_eq!(v.logits.len(), 4 * vocab);
    assert_fp32_slices_close(&v.logits, &want, "k-row causal window vs k sequential decode steps");
}

// ---------------------------------------------------------------------
// Golden: speculative ≡ plain, token for token
// ---------------------------------------------------------------------

#[test]
fn speculative_greedy_equals_plain_greedy_all_families() {
    // fp32 verifier × W4 drafter on every family: the committed stream
    // must be exactly the plain fp32 greedy stream, while real drafting
    // happened (drafted > 0).
    let fp = native();
    let w4 = native_w4();
    for model in FAMILIES {
        let weights = fp.load_model(model).unwrap();
        let ev = Evaluator::with_weights(&fp, fp.load_model(model).unwrap());
        let mut s = CorpusStream::new("wt2s", Split::Eval);
        let p = prompt(&mut s, weights.manifest.config.max_seq / 2);
        let max_new = weights.manifest.config.max_seq / 2;

        let plain = ev.generate(&p, max_new, None).unwrap();
        let drafter = SpecModel { backend: &w4, weights: &weights };
        let verifier = SpecModel { backend: &fp, weights: &weights };
        let mut gen = SpecGenerator::new(drafter, verifier, &SpecConfig::new(4)).unwrap();
        let mut sampler = Sampler::greedy();
        let (spec, stats) = gen.generate(&p, max_new, None, &mut sampler).unwrap();
        assert_eq!(spec, plain, "{model}: speculative greedy diverged from plain greedy");
        assert_eq!(spec.len(), max_new);
        assert!(stats.rounds > 0 && stats.drafted > 0, "{model}: no drafting happened");
    }
}

#[test]
fn speculative_matches_plain_under_seeded_sampler() {
    // beyond greedy: with one sampler draw per committed token, the
    // speculative stream equals the plain stream for any seeded sampler
    let fp = native();
    let w4 = native_w4();
    let weights = fp.load_model("gemma-micro").unwrap();
    let ev = Evaluator::with_weights(&fp, fp.load_model("gemma-micro").unwrap());
    let mut s = CorpusStream::new("ptbs", Split::Eval);
    let p = prompt(&mut s, 24);
    for seed in [3u64, 17] {
        let plain = ev
            .generate_with(&p, 12, None, &mut Sampler::top_k(8, 0.9, seed))
            .unwrap();
        let drafter = SpecModel { backend: &w4, weights: &weights };
        let verifier = SpecModel { backend: &fp, weights: &weights };
        let mut gen = SpecGenerator::new(drafter, verifier, &SpecConfig::new(3)).unwrap();
        let (spec, _) = gen
            .generate(&p, 12, None, &mut Sampler::top_k(8, 0.9, seed))
            .unwrap();
        assert_eq!(spec, plain, "seed {seed}: sampled speculative stream diverged");
    }
}

#[test]
fn speculative_honors_eos_and_budget_like_plain() {
    let fp = native();
    let w4 = native_w4();
    let weights = fp.load_model("opt-micro").unwrap();
    let ev = Evaluator::with_weights(&fp, fp.load_model("opt-micro").unwrap());
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let p = prompt(&mut s, 16);
    // use the 3rd plain token as EOS so both paths must stop early
    let plain_full = ev.generate(&p, 10, None).unwrap();
    let eos = plain_full[2];
    let plain = ev.generate(&p, 10, Some(eos)).unwrap();
    let drafter = SpecModel { backend: &w4, weights: &weights };
    let verifier = SpecModel { backend: &fp, weights: &weights };
    let mut gen = SpecGenerator::new(drafter, verifier, &SpecConfig::new(4)).unwrap();
    let mut sampler = Sampler::greedy();
    let (spec, _) = gen.generate(&p, 10, Some(eos), &mut sampler).unwrap();
    assert_eq!(spec, plain, "eos handling diverged");
    assert_eq!(*spec.last().unwrap(), eos);
    // budget: a tiny budget still matches exactly
    let (spec2, _) = gen.generate(&p, 2, None, &mut Sampler::greedy()).unwrap();
    assert_eq!(spec2, plain_full[..2], "budget clamp diverged");
}

#[test]
fn self_drafting_accepts_everything() {
    // drafter == verifier (same weights, same backend): every draft
    // must land, and the adaptive controller must widen k to its cap
    let fp = native();
    let weights = fp.load_model("qwen-micro").unwrap();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let p = prompt(&mut s, 16);
    let drafter = SpecModel { backend: &fp, weights: &weights };
    let verifier = SpecModel { backend: &fp, weights: &weights };
    let mut gen = SpecGenerator::new(drafter, verifier, &SpecConfig::new(2)).unwrap();
    let (toks, stats) = gen.generate(&p, 24, None, &mut Sampler::greedy()).unwrap();
    assert_eq!(toks.len(), 24);
    assert_eq!(stats.accepted, stats.drafted, "self-drafting must accept every draft");
    assert!((gen.controller().acceptance() - 1.0).abs() < 1e-9);
    assert_eq!(gen.controller().k(), 4, "k must widen to the 2×k cap on clean sweeps");
}

#[test]
fn drafter_weights_builds_any_registry_method() {
    use ttq_serve::quant::MethodSpec;
    let fp = native();
    let weights = fp.load_model("opt-micro").unwrap();
    for spec in ["rtn", "ttq:r=0", "nf:4", "prune:0.5"] {
        let m = MethodSpec::parse(spec).unwrap();
        let dw = drafter_weights(&weights, &m, &QuantSpec::new(4, 32)).unwrap();
        assert_ne!(dw.version(), weights.version(), "{spec}: fork must re-version");
        // quantized drafter still generates (structurally valid weights)
        let ev = Evaluator::with_weights(&fp, dw);
        let toks = ev.generate(&[BOS, 1, 2, 3], 4, None).unwrap();
        assert_eq!(toks.len(), 4, "{spec}");
    }
    // correlation methods have no serving-path stats source
    assert!(drafter_weights(&weights, &MethodSpec::gptq("c4s"), &QuantSpec::new(4, 32)).is_err());
}

// ---------------------------------------------------------------------
// Serving integration
// ---------------------------------------------------------------------

#[test]
fn server_speculative_stream_is_fp32_exact_across_requants() {
    // hair-trigger drift: the calibrator requantizes (and thereby swaps
    // the drafter) repeatedly mid-generation — the speculative stream
    // must still be exactly the fp32 model's greedy output
    let be = native();
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.policy = BatchPolicy { buckets: vec![1], linger: Duration::ZERO };
    cfg.max_new_tokens = 12;
    cfg.calib.drift_threshold = 1e-9;
    let mut server = Server::new(&be, cfg).unwrap();
    let prompt_len = server.max_seq() / 2;
    let mut s = CorpusStream::new("ptbs", Split::Eval);
    let p = prompt(&mut s, prompt_len);
    let rid = server.submit_speculative(p.clone());
    let events = server.drain().unwrap();

    // reference: plain greedy on pristine fp32 weights
    let ev = Evaluator::new(&be, "qwen-micro").unwrap();
    let want = ev.generate(&p, 12, None).unwrap();
    let got: Vec<i32> = events
        .iter()
        .filter_map(|e| match e {
            ServeEvent::Token { id, token, .. } if *id == rid => Some(*token),
            _ => None,
        })
        .collect();
    assert_eq!(got, want, "speculative serving stream is not fp32-exact");
    assert!(
        server.metrics.requants.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "test setup: requantization must fire mid-generation"
    );
    assert!(server.metrics.spec_rounds.load(std::sync::atomic::Ordering::Relaxed) > 0);
    match events.last().unwrap() {
        ServeEvent::Done { tokens, stop, .. } => {
            assert_eq!(tokens, &want);
            assert_eq!(*stop, StopReason::MaxNewTokens);
        }
        e => panic!("expected Done, got {e:?}"),
    }
}

#[test]
fn server_mixes_plain_and_speculative_requests() {
    let be = native();
    let mut cfg = ServerConfig::new("opt-micro");
    cfg.policy = BatchPolicy { buckets: vec![1, 4], linger: Duration::ZERO };
    cfg.max_new_tokens = 6;
    let mut server = Server::new(&be, cfg).unwrap();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    let plain_id = server.submit(prompt(&mut s, 20));
    let spec_id = server.submit_speculative(prompt(&mut s, 20));
    let plain_id2 = server.submit(prompt(&mut s, 24));
    let events = server.drain().unwrap();
    for rid in [plain_id, spec_id, plain_id2] {
        let toks: Vec<i32> = events
            .iter()
            .filter_map(|e| match e {
                ServeEvent::Token { id, token, .. } if *id == rid => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(toks.len(), 6, "request {rid}");
        let indices: Vec<usize> = events
            .iter()
            .filter_map(|e| match e {
                ServeEvent::Token { id, index, .. } if *id == rid => Some(*index),
                _ => None,
            })
            .collect();
        assert_eq!(indices, vec![0, 1, 2, 3, 4, 5], "request {rid} indices in order");
        assert_eq!(
            events
                .iter()
                .filter(|e| matches!(e, ServeEvent::Done { id, .. } if *id == rid))
                .count(),
            1
        );
    }
    assert_eq!(server.running(), 0);
    assert_eq!(server.cache_stats().active_seqs, 0, "verifier slots recycled");
    assert!(
        server.metrics.spec_rounds.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "the speculative request must have run speculative rounds"
    );
    assert!(
        server.metrics.decode_steps.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "the plain requests must have run batched decode steps"
    );
}

#[test]
fn done_reports_stop_reason() {
    let be = native();
    // MaxNewTokens: room to spare, budget exhausted
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.max_new_tokens = 3;
    let mut server = Server::new(&be, cfg).unwrap();
    let mut s = CorpusStream::new("wt2s", Split::Eval);
    server.submit(prompt(&mut s, 16));
    let events = server.drain().unwrap();
    assert!(matches!(
        events.last().unwrap(),
        ServeEvent::Done { stop: StopReason::MaxNewTokens, .. }
    ));

    // ContextFull: a full-window prompt leaves room for exactly 1 token
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.max_new_tokens = 16;
    let mut server = Server::new(&be, cfg).unwrap();
    let max_seq = server.max_seq();
    server.submit(prompt(&mut s, max_seq));
    let events = server.drain().unwrap();
    match events.last().unwrap() {
        ServeEvent::Done { tokens, stop, .. } => {
            assert_eq!(tokens.len(), 1);
            assert_eq!(*stop, StopReason::ContextFull);
        }
        e => panic!("expected Done, got {e:?}"),
    }

    // Eos: probe the second generated token, then stop on it
    let p = prompt(&mut s, 20);
    let mut cfg = ServerConfig::new("qwen-micro");
    cfg.max_new_tokens = 6;
    let mut probe = Server::new(&be, cfg.clone()).unwrap();
    probe.submit(p.clone());
    let second = probe
        .drain()
        .unwrap()
        .iter()
        .find_map(|e| match e {
            ServeEvent::Token { token, index: 1, .. } => Some(*token),
            _ => None,
        })
        .unwrap();
    cfg.eos = Some(second);
    let mut server = Server::new(&be, cfg).unwrap();
    server.submit(p);
    let events = server.drain().unwrap();
    assert!(matches!(
        events.last().unwrap(),
        ServeEvent::Done { stop: StopReason::Eos, .. }
    ));
}

#[test]
fn speculative_backpressure_and_slot_recycling() {
    // more speculative requests than KV slots: both the verifier slab
    // and the drafter slab must recycle cleanly
    let be = native();
    let mut cfg = ServerConfig::new("opt-micro");
    cfg.policy = BatchPolicy { buckets: vec![4], linger: Duration::ZERO };
    cfg.cache_slots = 2;
    cfg.max_new_tokens = 3;
    let mut server = Server::new(&be, cfg).unwrap();
    let mut s = CorpusStream::new("c4s", Split::Eval);
    let n = 5;
    for _ in 0..n {
        server.submit_speculative(prompt(&mut s, 20));
    }
    let events = server.drain().unwrap();
    let done = events
        .iter()
        .filter(|e| matches!(e, ServeEvent::Done { .. }))
        .count();
    assert_eq!(done, n, "every speculative request must complete with 2 KV slots");
    assert_eq!(server.cache_stats().active_seqs, 0);
}
