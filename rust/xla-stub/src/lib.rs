//! Minimal in-tree stand-in for the `xla` crate (PJRT bindings).
//!
//! The real PJRT CPU client links a prebuilt XLA extension that is not
//! available offline or on plain CI runners. This stub keeps the
//! runtime layer compiling with the same API surface:
//!
//! * [`Literal`] is **fully functional** — the pure-data half of the
//!   API (`vec1` / `scalar` / `reshape` / `to_vec` / `element_count` /
//!   `to_tuple`) that unit tests exercise;
//! * compilation/execution ([`PjRtClient::compile`],
//!   [`PjRtLoadedExecutable::execute`]) return errors, which every
//!   consumer already treats as "artifacts not ready" and skips
//!   gracefully.
//!
//! Swap the `xla` path dependency in the workspace manifest for the
//! upstream crate to execute real AOT HLO artifacts.

use std::fmt;
use std::path::Path;

/// Stub error type (the real crate's error also just `Display`s).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} needs the real PJRT runtime (swap the `xla` path \
         dependency for the upstream crate and run `make artifacts`)"
    ))
}

/// Raw element storage of a [`Literal`].
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can store.
pub trait NativeType: Copy {
    fn to_payload(v: &[Self]) -> Payload;
    fn from_payload(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn to_payload(v: &[Self]) -> Payload {
        Payload::F32(v.to_vec())
    }

    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn to_payload(v: &[Self]) -> Payload {
        Payload::I32(v.to_vec())
    }

    fn from_payload(p: &Payload) -> Option<Vec<Self>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side tensor (or tuple of tensors).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { payload: T::to_payload(v), dims: vec![v.len() as i64] }
    }

    pub fn scalar(v: f32) -> Literal {
        Literal { payload: Payload::F32(vec![v]), dims: Vec::new() }
    }

    pub fn tuple(elems: Vec<Literal>) -> Literal {
        let n = elems.len() as i64;
        Literal { payload: Payload::Tuple(elems), dims: vec![n] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!(
                "reshape to {dims:?} holds {want} elements, literal has {have}"
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(t) => t.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_payload(&self.payload)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(t) => Ok(t),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO-text module (the stub only retains the text).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path:?}: {e}")))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. Construction succeeds (callers probe platform
/// info without artifacts); compilation is where the stub stops.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu (xla stub — artifacts cannot execute)".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling HLO"))
    }
}

/// Device-resident output buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("buffer readback"))
    }
}

/// Compiled executable. Never constructed by the stub — [`PjRtClient::compile`]
/// errors first — but the type keeps every consumer signature compiling.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let l = Literal::vec1(&[7i32, 8, 9]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(2.5);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![2.5]);
        let t = Literal::tuple(vec![s.clone(), s]);
        assert_eq!(t.clone().to_tuple().unwrap().len(), 2);
        assert!(Literal::scalar(0.0).to_tuple().is_err());
    }

    #[test]
    fn executor_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let comp = XlaComputation::from_proto(&HloModuleProto { _text: String::new() });
        assert!(client.compile(&comp).is_err());
    }
}
