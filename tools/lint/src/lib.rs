//! Repo-specific invariant lints for the `ttq-serve` tree.
//!
//! `cargo run -p repo-lint` walks `rust/src` and enforces the
//! concurrency contracts that `rustc` cannot express (see
//! `docs/CONCURRENCY.md` for the full rationale):
//!
//! * **R1** — no direct thread spawning (`thread::spawn`,
//!   `thread::scope`, `Builder::new`) outside the sync shim. Every
//!   thread must come from `crate::sync::thread::spawn_named` so the
//!   loom build can intercept it. The single retained scoped-spawn
//!   baseline in `bench/throughput.rs` is allowlisted.
//! * **R2** — no `unsafe` outside `linalg/pool.rs`, `linalg/simd.rs`
//!   and `sync/` (mirrored by `#![forbid(unsafe_code)]` in every other
//!   module; the lint catches removal of the attribute).
//! * **R3** — no `.unwrap()` / `.expect()` on the serving path
//!   (`coordinator`, `backend`, `kvcache`, `specdec`): these modules
//!   degrade via error enums, never by unwinding mid-batch. Exact
//!   identifier matching, so `unwrap_or` / `unwrap_or_else` are fine.
//! * **R4** — no direct `std::sync` in the shimmed modules
//!   (`linalg/pool.rs`, `backend/native.rs`): they must import from
//!   `crate::sync` so `--cfg loom` swaps in the model primitives.
//! * **R5** — no raw `Instant::now` in `linalg/` (except the pool
//!   itself) or `backend/native.rs`: kernel timing belongs to the
//!   pool's single `kernel_us` counter, not to ad-hoc probes inside
//!   kernels where they would skew the accounting the
//!   `kernel_us_accounting_benign` model reasons about.
//! * **R6** — observability discipline on the serving path
//!   (`coordinator`, `backend`, `kvcache`, `specdec`): no
//!   `println!`/`eprintln!` (telemetry flows through `Metrics`, the
//!   span ring and the exporters, never stdout), and no raw
//!   `Instant::now` (timestamps come from `obs::Clock`, so tests can
//!   pin a deterministic clock). `backend/native.rs` is excluded from
//!   the `Instant` half — R5 already owns its kernel timing.
//! * **R7** — profiler attribution coverage (`backend/`): every
//!   `WorkerPool` dispatch must go through `run_rows_site` with a
//!   `KernelSite`-bearing `KernelCall`; bare `.run_rows(...)` leaves
//!   kernel wall time unattributed and breaks the ≥ 90% coverage gate
//!   in `benches/kernel_profile.rs`.
//! * **R8** — vendor intrinsics (`std::arch` / `core::arch`, including
//!   the feature-detection macros) confined to `linalg/simd.rs`: the
//!   SIMD dispatch module is the one place where the W4-exact /
//!   fp32-ULP numerics contract and the `TTQ_FORCE_SCALAR` kill-switch
//!   are enforced, so scattered intrinsics elsewhere would bypass both.
//!
//! The scanner is a hand-rolled lexer (this tree is dependency-free by
//! policy, so no `syn`): comments, string/char literals, raw strings
//! and lifetimes are stripped before matching, identifiers are matched
//! exactly, and `#[cfg(test)]` items are exempt from R1/R3/R5.

use std::fmt;

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Repo-relative path (forward slashes).
    pub file: String,
    /// 1-based line of the offending token.
    pub line: usize,
    /// Stable rule id, e.g. `"R1"`.
    pub rule: &'static str,
    /// Human-readable explanation with the sanctioned alternative.
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

// ---------------------------------------------------------------------
// lexer
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq, Eq)]
enum Tok {
    Ident(String),
    Punct(char),
}

#[derive(Debug, Clone)]
struct Token {
    line: usize,
    tok: Tok,
    in_test: bool,
}

fn skip_string(b: &[char], mut i: usize, line: &mut usize) -> usize {
    debug_assert_eq!(b[i], '"');
    i += 1;
    while i < b.len() {
        match b[i] {
            '\\' => {
                if i + 1 < b.len() && b[i + 1] == '\n' {
                    *line += 1;
                }
                i += 2;
            }
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

fn skip_char_lit(b: &[char], mut i: usize, line: &mut usize) -> usize {
    debug_assert_eq!(b[i], '\'');
    i += 1;
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\'' => return i + 1,
            '\n' => {
                // unterminated; bail so the lexer resynchronizes
                *line += 1;
                return i + 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// `i` points at the first `#` or `"` after an `r`/`b`/`br` prefix.
fn skip_raw_string(b: &[char], mut i: usize, line: &mut usize) -> usize {
    let mut hashes = 0usize;
    while i < b.len() && b[i] == '#' {
        hashes += 1;
        i += 1;
    }
    if i >= b.len() || b[i] != '"' {
        return i; // not actually a raw string; resynchronize
    }
    i += 1;
    while i < b.len() {
        if b[i] == '\n' {
            *line += 1;
            i += 1;
        } else if b[i] == '"' {
            let mut k = 0;
            while k < hashes && i + 1 + k < b.len() && b[i + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                return i + 1 + hashes;
            }
            i += 1;
        } else {
            i += 1;
        }
    }
    i
}

fn lex(src: &str) -> Vec<(usize, Tok)> {
    let b: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c == '/' && i + 1 < b.len() && b[i + 1] == '/' {
            while i < b.len() && b[i] != '\n' {
                i += 1;
            }
        } else if c == '/' && i + 1 < b.len() && b[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < b.len() && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
        } else if c == '"' {
            i = skip_string(&b, i, &mut line);
        } else if c == '\'' {
            let is_lifetime = i + 2 < b.len()
                && (b[i + 1].is_alphabetic() || b[i + 1] == '_')
                && b[i + 2] != '\'';
            if is_lifetime {
                i += 1;
                while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
            } else {
                i = skip_char_lit(&b, i, &mut line);
            }
        } else if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            let id: String = b[start..i].iter().collect();
            let raw_prefix = matches!(id.as_str(), "r" | "b" | "br")
                && i < b.len()
                && (b[i] == '"' || b[i] == '#');
            if raw_prefix {
                i = skip_raw_string(&b, i, &mut line);
            } else {
                out.push((line, Tok::Ident(id)));
            }
        } else if c.is_ascii_digit() {
            // numeric literal; `.` only continues it when a digit
            // follows (so `tuple.0.unwrap()` still yields `.unwrap`)
            while i < b.len() {
                let d = b[i];
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                } else if d == '.' && i + 1 < b.len() && b[i + 1].is_ascii_digit() {
                    i += 1;
                } else {
                    break;
                }
            }
        } else if c.is_whitespace() {
            i += 1;
        } else {
            out.push((line, Tok::Punct(c)));
            i += 1;
        }
    }
    out
}

/// Flag tokens inside `#[cfg(test)]`-gated items.
fn mark_test_regions(raw: Vec<(usize, Tok)>) -> Vec<Token> {
    let mut toks: Vec<Token> = raw
        .into_iter()
        .map(|(line, tok)| Token {
            line,
            tok,
            in_test: false,
        })
        .collect();
    let is = |t: &Token, s: &str| matches!(&t.tok, Tok::Ident(id) if id == s);
    let p = |t: &Token, c: char| t.tok == Tok::Punct(c);
    let mut i = 0usize;
    while i < toks.len() {
        if !(p(&toks[i], '#') && i + 1 < toks.len() && p(&toks[i + 1], '[')) {
            i += 1;
            continue;
        }
        // collect the attribute body up to its matching `]`
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut has_cfg = false;
        let mut has_test = false;
        let mut has_not = false;
        while j < toks.len() && depth > 0 {
            if p(&toks[j], '[') {
                depth += 1;
            } else if p(&toks[j], ']') {
                depth -= 1;
            } else if is(&toks[j], "cfg") {
                has_cfg = true;
            } else if is(&toks[j], "test") {
                has_test = true;
            } else if is(&toks[j], "not") {
                has_not = true;
            }
            j += 1;
        }
        if !(has_cfg && has_test && !has_not) {
            i = j;
            continue;
        }
        // the attribute gates the next item: skip trailing attributes,
        // then either a `{ .. }` body or a `;`-terminated item
        let mut k = j;
        while k + 1 < toks.len() && p(&toks[k], '#') && p(&toks[k + 1], '[') {
            // another attribute on the same item
            let mut d = 1usize;
            k += 2;
            while k < toks.len() && d > 0 {
                if p(&toks[k], '[') {
                    d += 1;
                } else if p(&toks[k], ']') {
                    d -= 1;
                }
                k += 1;
            }
        }
        let mut brace = 0usize;
        let mut entered = false;
        while k < toks.len() {
            if p(&toks[k], '{') {
                brace += 1;
                entered = true;
            } else if p(&toks[k], '}') {
                brace = brace.saturating_sub(1);
                if entered && brace == 0 {
                    k += 1;
                    break;
                }
            } else if p(&toks[k], ';') && !entered {
                k += 1;
                break;
            }
            k += 1;
        }
        for t in &mut toks[j..k.min(toks.len())] {
            t.in_test = true;
        }
        i = k.max(j);
    }
    toks
}

// ---------------------------------------------------------------------
// pattern matching
// ---------------------------------------------------------------------

/// Pattern element: `"::"`, `"."`, or an exact identifier.
fn pat_toks(pat: &[&str]) -> Vec<Tok> {
    let mut out = Vec::new();
    for p in pat {
        match *p {
            "::" => {
                out.push(Tok::Punct(':'));
                out.push(Tok::Punct(':'));
            }
            "." => out.push(Tok::Punct('.')),
            id => out.push(Tok::Ident(id.to_string())),
        }
    }
    out
}

fn find_matches(toks: &[Token], pat: &[&str], skip_test: bool) -> Vec<usize> {
    let pt = pat_toks(pat);
    let mut hits = Vec::new();
    if pt.is_empty() || toks.len() < pt.len() {
        return hits;
    }
    for i in 0..=(toks.len() - pt.len()) {
        if skip_test && toks[i].in_test {
            continue;
        }
        if (0..pt.len()).all(|k| toks[i + k].tok == pt[k]) {
            hits.push(i);
        }
    }
    hits
}

// ---------------------------------------------------------------------
// rules
// ---------------------------------------------------------------------

fn starts_with_any(path: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| path.starts_with(p))
}

/// Scan one file's source text against every applicable rule.
///
/// `path` is the repo-relative path with forward slashes (it selects
/// which rules apply); `src` is the file contents.
pub fn scan_str(path: &str, src: &str) -> Vec<Violation> {
    let toks = mark_test_regions(lex(src));
    let mut out = Vec::new();
    let mut push = |line: usize, rule: &'static str, msg: String| {
        out.push(Violation {
            file: path.to_string(),
            line,
            rule,
            msg,
        });
    };

    // R1: thread creation only via the sync shim
    let r1_exempt = starts_with_any(
        path,
        &["rust/src/sync/", "rust/src/bench/throughput.rs", "rust/tests/"],
    );
    if !r1_exempt {
        for pat in [
            &["thread", "::", "spawn"][..],
            &["thread", "::", "scope"][..],
            &["Builder", "::", "new"][..],
        ] {
            for i in find_matches(&toks, pat, true) {
                push(
                    toks[i].line,
                    "R1",
                    format!(
                        "direct thread creation (`{}`): use \
                         `crate::sync::thread::spawn_named` so the loom \
                         build can model it",
                        pat.join("")
                    ),
                );
            }
        }
    }

    // R2: `unsafe` confined to the pool, the sync shim and the SIMD
    // microkernels
    let r2_exempt = starts_with_any(
        path,
        &["rust/src/linalg/pool.rs", "rust/src/linalg/simd.rs", "rust/src/sync/"],
    );
    if !r2_exempt {
        for i in find_matches(&toks, &["unsafe"], false) {
            push(
                toks[i].line,
                "R2",
                "`unsafe` outside linalg/pool.rs, linalg/simd.rs and \
                 sync/: keep `#![forbid(unsafe_code)]` on this module \
                 and move the operation behind a checked pool/shim/simd \
                 API"
                    .to_string(),
            );
        }
    }

    // R3: serving path degrades via error enums, never unwinds
    let r3_applies = starts_with_any(
        path,
        &[
            "rust/src/coordinator/",
            "rust/src/backend/",
            "rust/src/kvcache/",
            "rust/src/specdec/",
        ],
    );
    if r3_applies {
        for pat in [&[".", "unwrap"][..], &[".", "expect"][..]] {
            for i in find_matches(&toks, pat, true) {
                push(
                    toks[i].line,
                    "R3",
                    format!(
                        "`{}` on the serving path: return \
                         `ServeError`/`SpecError` (or recover with \
                         `unwrap_or_else(PoisonError::into_inner)`) \
                         instead of unwinding mid-batch",
                        pat.join("")
                    ),
                );
            }
        }
    }

    // R4: shimmed modules must not reach std::sync directly
    let r4_applies = starts_with_any(
        path,
        &["rust/src/linalg/pool.rs", "rust/src/backend/native.rs"],
    );
    if r4_applies {
        for i in find_matches(&toks, &["std", "::", "sync"], false) {
            push(
                toks[i].line,
                "R4",
                "`std::sync` in a loom-shimmed module: import from \
                 `crate::sync` so `--cfg loom` swaps in the model \
                 primitives"
                    .to_string(),
            );
        }
    }

    // R5: kernel timing belongs to the pool's kernel_us counter
    let r5_applies = (starts_with_any(path, &["rust/src/linalg/"])
        && path != "rust/src/linalg/pool.rs")
        || path == "rust/src/backend/native.rs";
    if r5_applies {
        for i in find_matches(&toks, &["Instant", "::", "now"], true) {
            push(
                toks[i].line,
                "R5",
                "raw `Instant::now` inside kernel code: timing belongs \
                 to the pool's `kernel_us` counter (WorkerPool::run_rows \
                 already accounts dispatch time)"
                    .to_string(),
            );
        }
    }

    // R6: serving-path telemetry goes through obs, not stdout/Instant
    let r6_applies = starts_with_any(
        path,
        &[
            "rust/src/coordinator/",
            "rust/src/backend/",
            "rust/src/kvcache/",
            "rust/src/specdec/",
        ],
    );
    if r6_applies {
        for ident in ["println", "eprintln"] {
            for i in find_matches(&toks, &[ident], true) {
                push(
                    toks[i].line,
                    "R6",
                    format!(
                        "`{ident}!` on the serving path: emit through \
                         `Metrics` / the span ring / `obs::export`, \
                         never stdout (CLI and examples own printing)"
                    ),
                );
            }
        }
        // backend/native.rs kernel timing is R5's jurisdiction
        if path != "rust/src/backend/native.rs" {
            for i in find_matches(&toks, &["Instant", "::", "now"], true) {
                push(
                    toks[i].line,
                    "R6",
                    "raw `Instant::now` on the serving path: read \
                     `obs::Clock` instead so deterministic-clock tests \
                     can replay exact span trees"
                        .to_string(),
                );
            }
        }
    }

    // R7: backend kernel dispatches carry a KernelSite for attribution
    let r7_applies = starts_with_any(path, &["rust/src/backend/"]);
    if r7_applies {
        for i in find_matches(&toks, &[".", "run_rows"], true) {
            push(
                toks[i].line,
                "R7",
                "bare `.run_rows(...)` in the backend: dispatch through \
                 `run_rows_site` with a `KernelCall` so the profiler can \
                 attribute the kernel time (attribution-coverage gate)"
                    .to_string(),
            );
        }
    }

    // R8: vendor intrinsics confined to the SIMD dispatch module
    let r8_exempt = starts_with_any(path, &["rust/src/linalg/simd.rs"]);
    if !r8_exempt {
        for pat in [&["std", "::", "arch"][..], &["core", "::", "arch"][..]] {
            for i in find_matches(&toks, pat, false) {
                push(
                    toks[i].line,
                    "R8",
                    format!(
                        "`{}` outside linalg/simd.rs: vendor intrinsics \
                         and feature detection live behind the \
                         `linalg::simd::Isa` dispatch (one place for the \
                         W4-exact / fp32-ULP numerics contract and the \
                         `TTQ_FORCE_SCALAR` kill-switch)",
                        pat.join("")
                    ),
                );
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(path: &str, src: &str) -> Vec<&'static str> {
        scan_str(path, src).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn r1_fires_on_direct_spawn() {
        let bad = "fn f() { std::thread::spawn(|| {}); }";
        assert_eq!(rules("rust/src/kvcache/mod.rs", bad), vec!["R1"]);
        let bad_scope = "fn f() { std::thread::scope(|s| {}); }";
        assert_eq!(rules("rust/src/quant/mod.rs", bad_scope), vec!["R1"]);
    }

    #[test]
    fn r1_allows_shim_and_baseline() {
        let shim = "fn f() { std::thread::Builder::new(); }";
        assert!(rules("rust/src/sync/mod.rs", shim).is_empty());
        let bench = "fn f() { std::thread::scope(|s| {}); }";
        assert!(rules("rust/src/bench/throughput.rs", bench).is_empty());
        let named = "fn f() { crate::sync::thread::spawn_named(\"x\", || {}); }";
        assert!(rules("rust/src/quant/mod.rs", named).is_empty());
    }

    #[test]
    fn r2_fires_on_unsafe_outside_pool() {
        let bad = "fn f() { unsafe { core::hint::unreachable_unchecked() } }";
        assert_eq!(rules("rust/src/quant/mod.rs", bad), vec!["R2"]);
        assert!(rules("rust/src/linalg/pool.rs", bad).is_empty());
        // the SIMD microkernel module is on the R2 allowlist too
        assert!(rules("rust/src/linalg/simd.rs", bad).is_empty());
    }

    #[test]
    fn r2_ignores_forbid_attribute_and_comments() {
        let good = "#![forbid(unsafe_code)]\n// unsafe in a comment\nfn f() {}";
        assert!(rules("rust/src/quant/mod.rs", good).is_empty());
    }

    #[test]
    fn r3_fires_on_serving_path_unwrap() {
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert_eq!(rules("rust/src/coordinator/server.rs", bad), vec!["R3"]);
        let bad2 = "fn f(x: Option<u32>) -> u32 { x.expect(\"boom\") }";
        assert_eq!(rules("rust/src/specdec/mod.rs", bad2), vec!["R3"]);
    }

    #[test]
    fn r3_exact_idents_and_test_mods_are_exempt() {
        let fine = "fn f(x: Option<u32>) -> u32 { x.unwrap_or_else(|| 0) }";
        assert!(rules("rust/src/backend/native.rs", fine).is_empty());
        let test_mod = "#[cfg(test)]\nmod tests {\n fn g(x: Option<u32>) -> u32 { x.unwrap() }\n}";
        assert!(rules("rust/src/kvcache/mod.rs", test_mod).is_empty());
        let outside = "fn h(x: Option<u32>) -> u32 { x.unwrap() }\n#[cfg(test)]\nmod tests {}";
        assert_eq!(rules("rust/src/kvcache/mod.rs", outside), vec!["R3"]);
    }

    #[test]
    fn r3_does_not_apply_off_serving_path() {
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert!(rules("rust/src/quant/mod.rs", bad).is_empty());
    }

    #[test]
    fn r4_fires_on_std_sync_in_shimmed_modules() {
        let bad = "use std::sync::Mutex;";
        assert_eq!(rules("rust/src/linalg/pool.rs", bad), vec!["R4"]);
        assert_eq!(rules("rust/src/backend/native.rs", bad), vec!["R4"]);
        assert!(rules("rust/src/runtime/mod.rs", bad).is_empty());
        let good = "use crate::sync::Mutex;";
        assert!(rules("rust/src/linalg/pool.rs", good).is_empty());
    }

    #[test]
    fn r5_fires_on_instant_in_kernels_but_not_pool() {
        let bad = "fn f() { let t = std::time::Instant::now(); }";
        assert_eq!(rules("rust/src/linalg/svd.rs", bad), vec!["R5"]);
        assert_eq!(rules("rust/src/backend/native.rs", bad), vec!["R5"]);
        assert!(rules("rust/src/linalg/pool.rs", bad).is_empty());
        assert!(rules("rust/src/bench/throughput.rs", bad).is_empty());
    }

    #[test]
    fn strings_and_raw_strings_never_match() {
        let good = r###"fn f() {
            let s = "std::thread::spawn unsafe .unwrap()";
            let r = r#"Instant::now"#;
        }"###;
        assert!(rules("rust/src/coordinator/server.rs", good).is_empty());
        assert!(rules("rust/src/linalg/svd.rs", good).is_empty());
    }

    #[test]
    fn lifetimes_do_not_derail_the_lexer() {
        let good = "fn f<'a>(x: &'a str) -> &'a str { x }\nfn g() -> char { 'x' }";
        assert!(rules("rust/src/coordinator/server.rs", good).is_empty());
    }

    #[test]
    fn tuple_field_access_still_matches_unwrap() {
        let bad = "fn f(x: (Option<u32>,)) -> u32 { x.0.unwrap() }";
        assert_eq!(rules("rust/src/specdec/mod.rs", bad), vec!["R3"]);
    }

    #[test]
    fn r6_fires_on_serving_path_println() {
        let bad = "fn f() { println!(\"tok/s {}\", 3); }";
        assert_eq!(rules("rust/src/coordinator/server.rs", bad), vec!["R6"]);
        let bad2 = "fn f() { eprintln!(\"oops\"); }";
        assert_eq!(rules("rust/src/kvcache/mod.rs", bad2), vec!["R6"]);
        // printing is the CLI's and the examples' job
        assert!(rules("rust/src/main.rs", bad).is_empty());
        assert!(rules("rust/src/bench/throughput.rs", bad).is_empty());
    }

    #[test]
    fn r6_fires_on_serving_path_instant() {
        let bad = "fn f() { let t = std::time::Instant::now(); }";
        assert_eq!(rules("rust/src/coordinator/server.rs", bad), vec!["R6"]);
        assert_eq!(rules("rust/src/specdec/mod.rs", bad), vec!["R6"]);
        // native.rs kernel timing stays R5's finding, never double-reported
        assert_eq!(rules("rust/src/backend/native.rs", bad), vec!["R5"]);
        // the clock abstraction itself legitimately reads Instant
        assert!(rules("rust/src/obs/clock.rs", bad).is_empty());
    }

    #[test]
    fn r6_exempts_test_modules() {
        let test_mod =
            "#[cfg(test)]\nmod tests {\n fn g() { println!(\"dbg\"); let t = std::time::Instant::now(); }\n}";
        assert!(rules("rust/src/coordinator/metrics.rs", test_mod).is_empty());
    }

    #[test]
    fn r7_fires_on_unattributed_backend_dispatch() {
        let bad = "fn f(p: &WorkerPool) { p.run_rows(&mut y, 4, 8, 64, |r0, rows| {}); }";
        assert_eq!(rules("rust/src/backend/native.rs", bad), vec!["R7"]);
        // the attributed dispatch and non-backend callers are fine
        let good = "fn f(p: &WorkerPool) { p.run_rows_site(&mut y, 4, 8, 64, call, |r0, rows| {}); }";
        assert!(rules("rust/src/backend/native.rs", good).is_empty());
        assert!(rules("rust/src/linalg/pool.rs", bad).is_empty());
        let test_mod =
            "#[cfg(test)]\nmod tests {\n fn g(p: &WorkerPool) { p.run_rows(&mut y, 1, 1, 1, |a, b| {}); }\n}";
        assert!(rules("rust/src/backend/native.rs", test_mod).is_empty());
    }

    #[test]
    fn r8_fires_on_intrinsics_outside_simd() {
        let bad = "fn f() { let v = unsafe { std::arch::x86_64::_mm256_setzero_ps() }; }";
        // R2 (unsafe) and R8 (intrinsics) both fire outside the allowlists
        assert_eq!(rules("rust/src/backend/native.rs", bad), vec!["R2", "R8"]);
        let detect = "fn f() -> bool { std::arch::is_x86_feature_detected!(\"avx2\") }";
        assert_eq!(rules("rust/src/linalg/pool.rs", detect), vec!["R8"]);
        let core_arch = "use core::arch::aarch64::vaddq_f32;";
        assert_eq!(rules("rust/src/quant/pack.rs", core_arch), vec!["R8"]);
        // the dispatch module itself is the sanctioned home
        assert!(rules("rust/src/linalg/simd.rs", bad).is_empty());
        assert!(rules("rust/src/linalg/simd.rs", core_arch).is_empty());
        // R8 applies in test code too (no cfg(test) exemption): a
        // differential test must go through the Isa dispatch
        let test_mod = "#[cfg(test)]\nmod tests { use core::arch::x86_64::*; }";
        assert_eq!(rules("rust/src/util/mod.rs", test_mod), vec!["R8"]);
    }

    #[test]
    fn violation_display_is_greppable() {
        let v = scan_str(
            "rust/src/coordinator/server.rs",
            "fn f(x: Option<u32>) -> u32 { x.unwrap() }",
        );
        let s = v[0].to_string();
        assert!(s.contains("rust/src/coordinator/server.rs:1: [R3]"), "{s}");
    }
}
