//! `cargo run -p repo-lint` — walk `rust/src` (and the loom test
//! target) and enforce the repo's concurrency-invariant lints. Exits
//! non-zero and prints every violation when the tree is dirty; see
//! `repo_lint` (src/lib.rs) for the rule catalogue and
//! `docs/CONCURRENCY.md` for the rationale.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("repo-lint: cannot read {}: {e}", dir.display());
            return;
        }
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn main() -> ExitCode {
    // tools/lint/ -> tools/ -> repo root
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("repo root above tools/lint")
        .to_path_buf();

    let mut files = Vec::new();
    collect_rs(&root.join("rust").join("src"), &mut files);
    collect_rs(&root.join("rust").join("tests"), &mut files);
    files.sort();

    let mut violations = 0usize;
    for file in &files {
        let rel = file
            .strip_prefix(&root)
            .unwrap_or(file)
            .to_string_lossy()
            .replace('\\', "/");
        let src = match fs::read_to_string(file) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("repo-lint: cannot read {rel}: {e}");
                violations += 1;
                continue;
            }
        };
        for v in repo_lint::scan_str(&rel, &src) {
            eprintln!("{v}");
            violations += 1;
        }
    }

    if violations > 0 {
        eprintln!("repo-lint: {violations} violation(s) in {} files", files.len());
        ExitCode::FAILURE
    } else {
        println!("repo-lint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    }
}
